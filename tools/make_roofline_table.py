"""Generate the EXPERIMENTS.md §Roofline table from results/dryrun/*.json."""

import glob
import json
import os
import sys

ARCH_ORDER = [
    "granite-8b", "qwen2-7b", "qwen1.5-110b", "h2o-danube-3-4b",
    "deepseek-moe-16b", "mixtral-8x22b", "zamba2-1.2b", "whisper-base",
    "chameleon-34b", "rwkv6-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_t(x):
    return f"{x:.3g}"


def main(result_dir="results/dryrun", mesh="single"):
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            path = os.path.join(result_dir, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(path):
                rows.append(f"| {arch} | {shape} | — | — | — | — | (not run) | — | — |")
                continue
            with open(path) as f:
                r = json.load(f)
            if r.get("skipped"):
                rows.append(
                    f"| {arch} | {shape} | — | — | — | — | SKIP: full attention | — | — |")
                continue
            if not r.get("ok"):
                rows.append(f"| {arch} | {shape} | — | — | — | — | FAIL | — | — |")
                continue
            roof = r["roofline"]
            mem = r["memory"]
            peak = mem["peak_estimate_bytes"] / 2**30
            fits = "✓" if mem["peak_estimate_bytes"] <= mem["hbm_per_device"] else f"✗ {peak:.0f}GiB"
            tc, tm, tl = roof["t_compute_s"], roof["t_memory_s"], roof["t_collective_s"]
            dom = roof["dominant"]
            frac = tc / max(tc, tm, tl) if max(tc, tm, tl) else 0
            rows.append(
                f"| {arch} | {shape} | {fmt_t(tc)} | {fmt_t(tm)} | {fmt_t(tl)} "
                f"| {dom} | {fits} | {frac:.2f} | {r['useful_ratio']:.2f} |"
            )
    header = (
        "| arch | shape | T_compute (s) | T_memory (s) | T_collective (s) "
        "| dominant | fits 16 GiB | roofline frac | useful (6ND/HLO) |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    print(header)
    print("\n".join(rows))


if __name__ == "__main__":
    main(*sys.argv[1:])

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` widens sweeps;
``--only=fig3,fig5`` selects modules.
"""

from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (  # noqa: E402
    fig3_traffic_indexing,
    fig4_fish_visibility,
    fig5_effect_inversion,
    fig6_traffic_scaleup,
    fig7_fish_scaleup,
    fig8_load_balance,
    roofline_report,
    table2_validation,
)
from benchmarks.common import emit  # noqa: E402

MODULES = {
    "fig3": fig3_traffic_indexing,
    "fig4": fig4_fish_visibility,
    "fig5": fig5_effect_inversion,
    "fig6": fig6_traffic_scaleup,
    "fig7": fig7_fish_scaleup,
    "fig8": fig8_load_balance,
    "table2": table2_validation,
    "roofline": roofline_report,
}


def main() -> None:
    quick = "--full" not in sys.argv
    only = None
    for a in sys.argv[1:]:
        if a.startswith("--only="):
            only = a.split("=", 1)[1].split(",")
    print("name,us_per_call,derived")
    failures = 0
    for key, mod in MODULES.items():
        if only and key not in only:
            continue
        try:
            emit(mod.run(quick=quick))
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{key}_ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Subprocess benchmark body: distributed throughput measurements.

Usage: dist_bench.py <scenario> [args...]; prints JSON on the last line.
Scenarios:
  inversion <ticks>        — predator scatter (2-pass) vs inverted (1-pass)
  scaleup <sim> <n_per>    — agent-ticks/s at the current device count
  loadbalance <epochs>     — drifting fish ± load balancing epoch times
"""

import json
import sys
import time

import numpy as np


def main():
    scenario = sys.argv[1]
    import jax

    n_dev = jax.device_count()

    if scenario == "inversion":
        ticks = int(sys.argv[2]) if len(sys.argv) > 2 else 20
        from repro.core.distribute import DistEngine
        from repro.sims.predator import init_population, make_predator_sim

        n = 240 * n_dev
        out = {}
        for label, inverted in (("two_pass", False), ("inverted", True)):
            sim = make_predator_sim(world=(10.0 * n_dev, 10.0), inverted=inverted)
            state = init_population(
                sim, n_prey=int(n * 0.9), n_pred=n - int(n * 0.9),
                capacity=int(n * 1.4), seed=0,
            )
            eng = DistEngine(sim, n_agents_hint=n, capacity_factor=4.0)
            assert eng.cfg.two_pass is (not inverted)
            bounds = eng.uniform_bounds()
            st = eng.distribute(state, bounds)
            st, _ = eng.run_epoch(st, bounds, n_ticks=2, seed=0)  # warmup
            t0 = time.perf_counter()
            st, _ = eng.run_epoch(st, bounds, n_ticks=ticks, seed=0, t0=2)
            dt = time.perf_counter() - t0
            out[label] = {"s": dt, "agent_ticks_per_s": n * ticks / dt}
        out["speedup"] = out["two_pass"]["s"] / out["inverted"]["s"]
        print(json.dumps(out))

    elif scenario == "scaleup":
        sim_name = sys.argv[2]
        n_per = int(sys.argv[3])
        ticks = int(sys.argv[4]) if len(sys.argv) > 4 else 20
        n = n_per * n_dev
        if sim_name == "traffic":
            from repro.sims.traffic import init_traffic, make_traffic_sim

            length = 2000.0 * n_dev  # scale the road with the cluster
            sim = make_traffic_sim(length=length)
            state = init_traffic(sim, n=n, capacity=int(n * 1.3), seed=0)
        else:
            from repro.sims.fish import init_school, make_fish_sim

            sim = make_fish_sim(world=(15.0 * n_dev, 10.0))
            state = init_school(
                sim, n=n, capacity=int(n * 1.3), seed=0, spread=3.0 * n_dev
            )
        if n_dev == 1:
            from repro.core import Engine

            eng = Engine(sim, n_agents_hint=n, cell_capacity=192)
            eng.run(state, n_ticks=2, seed=0)
            t0 = time.perf_counter()
            eng.run(state, n_ticks=ticks, seed=0)
            dt = time.perf_counter() - t0
        else:
            from repro.core.distribute import DistEngine

            eng = DistEngine(sim, n_agents_hint=n, capacity_factor=4.0,
                             cell_capacity=192)
            bounds = eng.uniform_bounds()
            st = eng.distribute(state, bounds)
            st, _ = eng.run_epoch(st, bounds, n_ticks=2, seed=0)
            t0 = time.perf_counter()
            eng.run_epoch(st, bounds, n_ticks=ticks, seed=0, t0=2)
            dt = time.perf_counter() - t0
        print(json.dumps({
            "n_dev": n_dev, "agents": n,
            "agent_ticks_per_s": n * ticks / dt, "s": dt,
        }))

    elif scenario == "loadbalance":
        epochs = int(sys.argv[2]) if len(sys.argv) > 2 else 5
        from repro.core.distribute import DistEngine
        from repro.core.master import Master, MasterConfig
        from repro.sims.fish import init_school, make_fish_sim

        n = 300 * n_dev
        sim = make_fish_sim(world=(15.0 * n_dev, 10.0), omega=1.2, noise=0.03)
        state0 = init_school(sim, n=n, capacity=2 * n, seed=0,
                             informed_fraction=0.25)
        out = {}
        for lb in (True, False):
            eng = DistEngine(sim, n_agents_hint=n, capacity_factor=6.0,
                             cell_capacity=256)
            m = Master(eng, MasterConfig(
                ticks_per_epoch=20, checkpoint_every=0, load_balance=lb,
                lb_imbalance_threshold=1.15, seed=0))
            st = m.start(state0)
            times, imbs = [], []
            for _ in range(epochs):
                t0 = time.perf_counter()
                st, rep = m.run_epoch(st)
                times.append(time.perf_counter() - t0)
                imbs.append(rep.imbalance)
            out["lb" if lb else "no_lb"] = {
                "epoch_s": times, "imbalance": imbs,
            }
        print(json.dumps(out))
    else:
        raise SystemExit(f"unknown scenario {scenario}")


if __name__ == "__main__":
    main()

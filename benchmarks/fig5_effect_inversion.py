"""Fig. 5 — Predator: effect inversion eliminates the second reduce pass.

Runs the scatter-form (two-pass map-reduce-reduce) and the compiler-
inverted gather-form (single pass) of the identical predator script on a
multi-device mesh, reporting agent-tick throughput (paper: >20% gain).
"""

from __future__ import annotations

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, run_subprocess  # noqa: E402


def run(quick: bool = True, n_dev: int = 8):
    res = run_subprocess("dist_bench.py", ["inversion", "16" if quick else "64"], n_dev)
    rows = []
    for label in ("two_pass", "inverted"):
        r = res[label]
        rows.append((
            f"fig5_predator_{label}_{n_dev}dev",
            r["s"] * 1e6,
            f"{r['agent_ticks_per_s']:.0f} agent-ticks/s",
        ))
    rows.append((
        f"fig5_inversion_speedup_{n_dev}dev", 0.0, f"{res['speedup']:.3f}x"
    ))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

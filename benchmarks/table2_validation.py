"""Table 2 — MITSIM-model validation: RMSPE of aggregate lane statistics
between the BRASIL traffic program and the independent hand-coded
simulator (sims/traffic_oracle.py plays MITSIM's role — same driver
models, different codebase and RNG)."""

from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, time_fn  # noqa: E402
from repro.core import Engine  # noqa: E402
from repro.sims.traffic import init_traffic, make_traffic_sim  # noqa: E402
from repro.sims.traffic_oracle import (  # noqa: E402
    OracleParams,
    TrafficOracle,
    lane_statistics,
    rmspe,
)

N_LANES = 4


def run(quick: bool = True):
    n, ticks, warmup = (240, 80, 30) if quick else (600, 300, 100)
    length = 2000.0 if quick else 5000.0

    # BRASIL side
    sim = make_traffic_sim(length=length)
    eng = Engine(sim, n_agents_hint=n)
    state = init_traffic(sim, n=n, capacity=int(n * 1.2), seed=0)
    stats_b = []
    lane_prev = None
    for t in range(ticks):
        state, _ = eng.run(state, n_ticks=1, seed=0, t0=t)
        alive = np.asarray(state.alive)
        lane = np.asarray(state.fields["lane"])[alive]
        v = np.asarray(state.fields["v"])[alive]
        x = np.asarray(state.fields["x"])[alive]
        changes = (
            np.zeros(len(lane), bool) if lane_prev is None or len(lane_prev) != len(lane)
            else lane_prev != lane
        )
        if t >= warmup:
            stats_b.append(lane_statistics(x, lane, v, changes, N_LANES, length))
        lane_prev = lane
    stats_b = np.mean(stats_b, axis=0)  # [lane, (dens, vel, chg)]

    # oracle side
    p = OracleParams(length=length)
    orc = TrafficOracle(p, seed=4242)
    rs = np.random.RandomState(0)
    x = rs.uniform(0, length, n)
    lane = rs.randint(0, N_LANES, n).astype(float)
    v = rs.uniform(10, 24, n)
    stats_o = []
    for t in range(ticks):
        x, lane, v, chg = orc.step(x, lane, v)
        if t >= warmup:
            stats_o.append(lane_statistics(x, lane, v, chg, N_LANES, length))
    stats_o = np.mean(stats_o, axis=0)

    rows = []
    metric_names = ["avg_density", "avg_velocity", "change_freq"]
    for mi, mname in enumerate([0, 1, 2]):
        for ln in range(N_LANES):
            e = rmspe([stats_o[ln, mi] + 1e-6], [stats_b[ln, mi] + 1e-6])
            rows.append((
                f"table2_L{ln + 1}_{metric_names[mi]}", 0.0, f"RMSPE={e:.3f}"
            ))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

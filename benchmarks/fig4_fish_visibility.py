"""Fig. 4 — Fish: indexing benefit vs visibility range.

As ρ grows, each KD-tree/grid probe returns more results, so the indexed
path degrades toward the quadratic baseline — but stays ahead (paper: "two
to three times improvement over a range of visibility values").
"""

from __future__ import annotations

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, time_fn  # noqa: E402
from repro.core import Engine  # noqa: E402
from repro.sims.fish import init_school, make_fish_sim  # noqa: E402


def run(quick: bool = True):
    n = 600 if quick else 2000
    ticks = 5
    rows = []
    for rho in ([0.5, 1.0, 2.0] if quick else [0.5, 1.0, 2.0, 3.0, 4.0]):
        sim = make_fish_sim(world=(40.0, 10.0), rho=rho)
        state = init_school(sim, n=n, capacity=int(n * 1.2), seed=0, spread=8.0)
        for index in ("grid", "brute"):
            eng = Engine(sim, n_agents_hint=n, index=index, cell_capacity=256)
            us = time_fn(
                lambda st: eng.run(st, n_ticks=ticks, seed=0)[0], state,
                warmup=1, iters=3,
            )
            tput = n * ticks / (us / 1e6)
            rows.append((f"fig4_fish_rho{rho}_{index}", us / ticks,
                         f"{tput:.0f} agent-ticks/s"))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

"""Fig. 8 — Load balancing: per-epoch time/imbalance for the drifting fish
school with the balancer on vs off."""

from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, run_subprocess  # noqa: E402


def run(quick: bool = True, n_dev: int = 4):
    res = run_subprocess(
        "dist_bench.py", ["loadbalance", "4" if quick else "8"], n_dev,
    )
    rows = []
    for label in ("lb", "no_lb"):
        r = res[label]
        mean_s = float(np.mean(r["epoch_s"][1:]))  # skip compile epoch
        final_imb = r["imbalance"][-1]
        rows.append((
            f"fig8_{label}_{n_dev}dev", mean_s * 1e6,
            f"epoch={mean_s:.3f}s final_imbalance={final_imb:.2f}",
        ))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

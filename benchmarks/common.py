"""Benchmark helpers: wall-clock timing of jitted callables + CSV emit."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
HELPERS = os.path.join(ROOT, "benchmarks", "helpers")


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_subprocess(script: str, args: list[str], n_dev: int, timeout=1800) -> dict:
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        PYTHONPATH=SRC + os.pathsep + HELPERS,
    )
    out = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(f"{script} failed:\n{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def emit(rows: list[tuple]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

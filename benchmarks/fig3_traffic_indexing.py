"""Fig. 3 — Traffic: spatial indexing vs segment length.

Without the grid index the query phase enumerates every vehicle pair
(quadratic in segment length at constant density); with it, cost grows
log-linearly.  Derived column = agent·ticks/second.
"""

from __future__ import annotations

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, time_fn  # noqa: E402
from repro.core import Engine  # noqa: E402
from repro.sims.traffic import init_traffic, make_traffic_sim  # noqa: E402

DENSITY = 0.08  # vehicles per meter of road (over 4 lanes)


def run(quick: bool = True):
    lengths = [1500, 3000, 6000] if quick else [1500, 3000, 6000, 12000, 24000]
    ticks = 5
    rows = []
    for length in lengths:
        n = int(length * DENSITY)
        sim = make_traffic_sim(length=float(length))
        state = init_traffic(sim, n=n, capacity=int(n * 1.2), seed=0)
        for index in ("grid", "brute"):
            if index == "brute" and n > 1000 and quick:
                pass  # keep the quadratic baseline bounded in quick mode
            eng = Engine(sim, n_agents_hint=n, index=index)
            us = time_fn(
                lambda st: eng.run(st, n_ticks=ticks, seed=0)[0], state,
                warmup=1, iters=3,
            )
            tput = n * ticks / (us / 1e6)
            rows.append((f"fig3_traffic_len{length}_{index}", us / ticks,
                         f"{tput:.0f} agent-ticks/s"))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

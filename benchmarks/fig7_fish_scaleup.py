"""Fig. 7 — Fish scale-up (the drifting-school workload that needs LB)."""

from __future__ import annotations

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import emit, run_subprocess  # noqa: E402


def run(quick: bool = True):
    devs = [1, 2, 4] if quick else [1, 2, 4, 8]
    n_per = 200 if quick else 500
    rows = []
    base = None
    for nd in devs:
        res = run_subprocess("dist_bench.py", ["scaleup", "fish", str(n_per)], nd)
        tput = res["agent_ticks_per_s"]
        base = base or tput
        rows.append((
            f"fig7_fish_scaleup_{nd}dev", res["s"] * 1e6,
            f"{tput:.0f} agent-ticks/s (x{tput / base:.2f})",
        ))
    return rows


if __name__ == "__main__":
    emit(run(quick="--full" not in sys.argv))

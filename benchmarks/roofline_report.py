"""Roofline table from the dry-run result cache (results/dryrun/*.json)."""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from benchmarks.common import ROOT, emit  # noqa: E402


def run(quick: bool = True, result_dir: str | None = None):
    result_dir = result_dir or os.path.join(ROOT, "results", "dryrun")
    rows = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*__single.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped"):
            rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0, "SKIP"))
            continue
        if not r.get("ok"):
            rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0, "FAIL"))
            continue
        roof = r["roofline"]
        t_star = max(roof["t_compute_s"], roof["t_memory_s"], roof["t_collective_s"])
        frac = roof["t_compute_s"] / t_star if t_star else 0.0
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}",
            t_star * 1e6,
            f"dom={roof['dominant']} frac_of_roofline={frac:.3f} "
            f"useful={r['useful_ratio']:.2f}",
        ))
    if not rows:
        rows.append(("roofline_no_results", 0.0,
                     "run: python -m repro.launch.dryrun --all"))
    return rows


if __name__ == "__main__":
    emit(run())

"""Quickstart: write a BRASIL agent class, compile it, run a simulation.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.brasil import AgentClass, Eff, Other, Self, abs_, rand_uniform  # noqa: E402
from repro.core import Engine, Simulation, uniform_population  # noqa: E402

# --- the paper's Fig. 2: simple fish with repulsion "forces" ----------------
Fish = AgentClass("Fish", position=("x", "y"), visibility=(1.0, 1.0))
Fish.state("x", reach=0.1).state("y", reach=0.1).state("vx").state("vy")
Fish.effect("avoidx", "sum").effect("avoidy", "sum").effect("count", "sum")

eps = 1e-1
Fish.emit("other", "avoidx", (Other("x") - Self("x")) / (abs_(Self("x") - Other("x")) + eps))
Fish.emit("other", "avoidy", (Other("y") - Self("y")) / (abs_(Self("y") - Other("y")) + eps))
Fish.emit("other", "count", 1.0)

Fish.update("x", Self("x") + Self("vx"))
Fish.update("y", Self("y") + Self("vy"))
Fish.update("vx", Self("vx") * 0.9 + 0.02 * (rand_uniform() - 0.5)
            + Eff("avoidx") / (Eff("count") + 1.0) * 0.01)
Fish.update("vy", Self("vy") * 0.9 + 0.02 * (rand_uniform() - 0.5)
            + Eff("avoidy") / (Eff("count") + 1.0) * 0.01)

# --- compile + run -----------------------------------------------------------
sim = Simulation.build(Fish, world_lo=(0, 0), world_hi=(20, 20))
n = 500
state = uniform_population(sim, n, capacity=600, seed=0)

engine = Engine(sim, n_agents_hint=n, index="grid")
print(f"grid: {engine.grid_spec}")
print(f"non-local effects -> map-reduce-reduce would be needed: "
      f"{sim.plan.has_nonlocal}")

for epoch in range(5):
    state, alive = engine.run(state, n_ticks=20, seed=0, t0=epoch * 20)
    x = np.asarray(state.fields["x"])[np.asarray(state.alive)]
    y = np.asarray(state.fields["y"])[np.asarray(state.alive)]
    print(f"epoch {epoch}: alive={int(alive[-1])} "
          f"x∈[{x.min():.2f},{x.max():.2f}] spread={x.std():.2f}")

# effect inversion: same program, single reduce pass
from repro.brasil import invert_effects  # noqa: E402

sim_inv = Simulation.build(invert_effects(Fish), world_lo=(0, 0), world_hi=(20, 20))
print(f"after compiler inversion, non-local effects: {sim_inv.plan.has_nonlocal}")

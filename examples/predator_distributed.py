"""Distributed predator simulation: map-reduce-reduce vs effect inversion.

Runs the predator model (non-local ``hurt`` effects) on 8 simulated
devices, first with the two-pass runtime, then with the compiler-inverted
single-pass script — the Fig. 5 experiment end to end, including the
master's checkpointing and the spawn hook.

    python examples/predator_distributed.py      # sets XLA_FLAGS itself
"""

import os
import sys

if "--_child" not in sys.argv:
    # re-exec with fake devices BEFORE jax initializes
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )
    os.execv(sys.executable, [sys.executable, __file__, "--_child"])

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time  # noqa: E402

import numpy as np  # noqa: E402

from repro.core.distribute import DistEngine  # noqa: E402
from repro.core.master import Master, MasterConfig  # noqa: E402
from repro.sims.predator import (  # noqa: E402
    init_population,
    make_predator_sim,
    make_spawn_hook,
)

N_PREY, N_PRED = 1800, 200
N = N_PREY + N_PRED

for inverted in (False, True):
    sim = make_predator_sim(world=(80.0, 10.0), inverted=inverted)
    label = "inverted (1 reduce pass)" if inverted else "scatter (2 reduce passes)"
    print(f"\n=== {label}; runtime two_pass={sim.plan.has_nonlocal} ===")
    engine = DistEngine(sim, n_agents_hint=N, capacity_factor=4.0)
    master = Master(
        engine,
        MasterConfig(ticks_per_epoch=10, checkpoint_every=2,
                     checkpoint_dir=f"/tmp/predator_ckpt_{inverted}", seed=0),
        epoch_hooks=[make_spawn_hook()],
    )
    state = master.start(init_population(sim, N_PREY, N_PRED, capacity=int(N * 1.5), seed=0))
    t0 = time.time()
    state, reports = master.run(state, n_epochs=4)
    dt = time.time() - t0
    total = sum(r.alive.sum() for r in reports[-1:])
    print(f"epochs=4 ticks=40 wall={dt:.2f}s  "
          f"throughput={N * 40 / dt:.0f} agent-ticks/s")
    for r in reports:
        print(f"  epoch {r.epoch}: alive/slab={r.alive.astype(int)} "
              f"imbalance={r.imbalance:.2f} rebalanced={r.rebalanced}")

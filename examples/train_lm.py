"""End-to-end LM training driver (deliverable (b)): trains a reduced
granite-style model for a few hundred steps on CPU through the full
production path — sharded synthetic pipeline, AdamW + cosine schedule,
grad clipping, checkpoints with restart.

    PYTHONPATH=src python examples/train_lm.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.launch.train import train_loop  # noqa: E402

ckpt = tempfile.mkdtemp(prefix="lm_ckpt_")
losses = train_loop(
    "granite-8b",
    steps=200,
    reduced_for_cpu=True,
    global_batch=8,
    seq_len=128,
    lr=3e-3,
    checkpoint_dir=ckpt,
    checkpoint_every=100,
)
first, last = float(np.mean(losses[:10])), float(np.mean(losses[-10:]))
print(f"\nloss first10={first:.3f} → last10={last:.3f}")
assert last < first - 0.2, "training did not reduce the loss!"

print("\n--- simulating preemption: restore from checkpoint and continue ---")
more = train_loop(
    "granite-8b",
    steps=250,
    reduced_for_cpu=True,
    global_batch=8,
    seq_len=128,
    lr=3e-3,
    checkpoint_dir=ckpt,
    restore=True,
)
print(f"resumed: final loss {float(np.mean(more[-10:])):.3f}")

"""Traffic simulation demo + validation against the hand-coded oracle
(Table 2's methodology at demo scale).

    PYTHONPATH=src python examples/traffic_demo.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import Engine  # noqa: E402
from repro.sims.traffic import init_traffic, make_traffic_sim  # noqa: E402
from repro.sims.traffic_oracle import OracleParams, TrafficOracle, rmspe  # noqa: E402

LENGTH, N, TICKS, WARM = 2000.0, 240, 80, 30

sim = make_traffic_sim(length=LENGTH)
eng = Engine(sim, n_agents_hint=N)
state = init_traffic(sim, n=N, capacity=300, seed=0)

speeds, lane_occ = [], []
for t in range(TICKS):
    state, _ = eng.run(state, n_ticks=1, seed=0, t0=t)
    alive = np.asarray(state.alive)
    v = np.asarray(state.fields["v"])[alive]
    lane = np.asarray(state.fields["lane"])[alive]
    if t >= WARM:
        speeds.append(v.mean())
        lane_occ.append([(np.abs(lane - ln) < 0.5).sum() for ln in range(4)])
    if t % 20 == 0:
        print(f"tick {t:3d}: mean v={v.mean():5.2f} m/s  "
              f"lanes={[int((np.abs(lane - ln) < 0.5).sum()) for ln in range(4)]}")

brasil_v = np.mean(speeds)
brasil_occ = np.mean(lane_occ, axis=0)

print("\nvalidating against the hand-coded simulator (MITSIM stand-in)...")
p = OracleParams(length=LENGTH)
orc = TrafficOracle(p, seed=999)
rs = np.random.RandomState(0)
x = rs.uniform(0, LENGTH, N)
lane = rs.randint(0, 4, N).astype(float)
v = rs.uniform(10, 24, N)
ovs, oocc = [], []
for t in range(TICKS):
    x, lane, v, _ = orc.step(x, lane, v)
    if t >= WARM:
        ovs.append(v.mean())
        oocc.append([(np.abs(lane - ln) < 0.5).sum() for ln in range(4)])

print(f"mean speed: BRASIL={brasil_v:.2f}  oracle={np.mean(ovs):.2f}  "
      f"RMSPE={rmspe([np.mean(ovs)], [brasil_v]):.3f}")
print(f"lane occupancy: BRASIL={np.round(brasil_occ, 1)}  "
      f"oracle={np.round(np.mean(oocc, axis=0), 1)}")

"""Master-level fault tolerance + load balancing (subprocess, fake devices)."""

import pytest

from test_distribute import run_helper


def test_checkpoint_restart_reexecutes_identically():
    """Paper recovery model: re-execute all ticks since the last checkpoint."""
    res = run_helper("master_check.py", ["checkpoint_resume"], 4)
    assert res["ok"], res


def test_elastic_restore_on_fewer_devices():
    """Mesh-agnostic checkpoints: resume on P/2 devices after 'node loss'."""
    res = run_helper("master_check.py", ["elastic"], 8)
    assert res["ok"], res


def test_load_balancing_reduces_imbalance():
    """Fig. 7/8: drifting fish school; LB keeps slab costs balanced."""
    res = run_helper("master_check.py", ["loadbalance"], 4, timeout=900)
    assert res["ok"], res

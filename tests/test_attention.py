"""Attention reference properties: streaming-softmax == naive, windowed ==
masked-naive, decode == row of full attention; RoPE/GQA invariants;
mamba2 chunked == sequential recurrence; rwkv6 chunked == sequential."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import attention as A


def naive_attention(q, k, v, causal=True, window=None):
    b, s, h, d = q.shape
    t = k.shape[1]
    g = h // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    sc = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32) * d**-0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vv)


@given(
    seed=st.integers(0, 100),
    s=st.sampled_from([16, 64, 128]),
    h=st.sampled_from([4]),
    kv=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=12, deadline=None)
def test_flash_matches_naive_causal(seed, s, h, kv):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    b, d = 2, 8
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    out = A.flash_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [8, 32, 96])
def test_windowed_matches_naive(window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    b, s, h, kv, d = 2, 128, 4, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    out = A.flash_attention(q, k, v, causal=True, window=window, q_chunk=32)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_matches_forward_row():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    b, s, h, kv, d = 2, 24, 4, 2, 8
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    full = naive_attention(q, k, v, causal=True)
    for pos in (0, 7, s - 1):
        out = A.decode_attention(q[:, pos:pos + 1], k, v, jnp.asarray(pos))
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(full[:, pos]), atol=2e-5
        )


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 16, 2, 8), jnp.float32)
    r = A.apply_rope(x, jnp.arange(16), 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 8))
    def dot_at(i, j):
        qi = A.apply_rope(q, jnp.asarray([i]), 10000.0)
        kj = A.apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


# ---------------------------------------------------------------------------
# recurrent blocks vs sequential oracles
# ---------------------------------------------------------------------------

def test_mamba2_chunked_matches_sequential():
    from repro.models import mamba2 as M

    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 64, 2, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_ = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32)

    got = M._ssd_chunked(x, dt, A_, B, C, chunk=16)

    # sequential oracle
    state = np.zeros((b, h, n, p))
    ref = np.zeros((b, s, h, p))
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, B, C))
    An = np.asarray(A_)
    for t in range(s):
        da = np.exp(dtn[:, t] * An[None, :])  # [b,h]
        state = state * da[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhnp", Bn[:, t], dtn[:, t], xn[:, t]
        )
        ref[:, t] = np.einsum("bn,bhnp->bhp", Cn[:, t], state)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=2e-4)


def test_rwkv6_chunked_matches_sequential():
    from repro.models import rwkv6 as R

    key = jax.random.PRNGKey(0)
    b, s, h, kd = 2, 48, 2, 4
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, s, h, kd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, h, kd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, h, kd), jnp.float32)
    logw = -jnp.exp(jax.random.normal(ks[3], (b, s, h, kd)) * 0.3)
    u = np.asarray(jax.random.normal(ks[4], (h, kd)) * 0.1)
    s0 = jnp.zeros((b, h, kd, kd), jnp.float32)

    got, s_fin = R._wkv_chunked(r, k, v, logw, jnp.asarray(u), s0, chunk=16)

    rn, kn, vn, wn = map(np.asarray, (r, k, v, logw))
    state = np.zeros((b, h, kd, kd))
    ref = np.zeros((b, s, h, kd))
    for t in range(s):
        kv = np.einsum("bhk,bhv->bhkv", kn[:, t], vn[:, t])
        eff = state + u[None, :, :, None] * kv
        ref[:, t] = np.einsum("bhk,bhkv->bhv", rn[:, t], eff)
        state = np.exp(wn[:, t])[:, :, :, None] * state + kv
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_fin), state, rtol=2e-4, atol=2e-4)

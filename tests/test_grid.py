"""Grid index properties: the candidate set must cover every pair within
the visibility bound (completeness — the KD-tree-replacement's contract)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import grid as G


def _candidate_pairs(gs, lo, x, y, alive):
    table, overflow = G.build_table(gs, lo, jnp.asarray(x), jnp.asarray(y), jnp.asarray(alive))
    cand, valid = G.candidates(gs, lo, table, jnp.asarray(x), jnp.asarray(y))
    assert int(overflow) == 0
    pairs = set()
    cand = np.asarray(cand)
    valid = np.asarray(valid)
    for i in range(len(x)):
        for j, ok in zip(cand[i], valid[i]):
            if ok:
                pairs.add((i, int(j)))
    return pairs


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 60),
    vis=st.floats(0.3, 3.0),
)
@settings(max_examples=30, deadline=None)
def test_stencil_covers_visibility(seed, n, vis):
    rs = np.random.RandomState(seed)
    ext = (10.0, 8.0)
    x = rs.uniform(0, ext[0], n).astype(np.float32)
    y = rs.uniform(0, ext[1], n).astype(np.float32)
    alive = rs.rand(n) > 0.2
    gs = G.make_grid(ext, (vis, vis), n, capacity_factor=50.0)
    pairs = _candidate_pairs(gs, (0.0, 0.0), x, y, alive)
    for i in range(n):
        for j in range(n):
            if i == j or not (alive[i] and alive[j]):
                continue
            if abs(x[i] - x[j]) <= vis and abs(y[i] - y[j]) <= vis:
                assert (i, j) in pairs, (
                    f"missing visible pair {i},{j}: "
                    f"d=({abs(x[i]-x[j]):.3f},{abs(y[i]-y[j]):.3f}) vis={vis}"
                )


def test_periodic_stencil_wraps():
    ext = (10.0, 4.0)
    gs = G.make_grid(ext, (1.0, 1.0), 4, capacity_factor=50.0, periodic=(True, False))
    x = np.asarray([0.2, 9.8], np.float32)
    y = np.asarray([1.0, 1.0], np.float32)
    alive = np.ones(2, bool)
    pairs = _candidate_pairs(gs, (0.0, 0.0), x, y, alive)
    assert (0, 1) in pairs and (1, 0) in pairs


def test_out_of_extent_clamps_into_border_cells():
    ext = (4.0, 4.0)
    gs = G.make_grid(ext, (1.0, 1.0), 4, capacity_factor=50.0)
    # one agent beyond the extent, one just inside: must still be candidates
    x = np.asarray([4.6, 3.9], np.float32)
    y = np.asarray([2.0, 2.0], np.float32)
    pairs = _candidate_pairs(gs, (0.0, 0.0), x, y, np.ones(2, bool))
    assert (0, 1) in pairs and (1, 0) in pairs


def test_capacity_overflow_detected():
    ext = (4.0, 4.0)
    gs = G.GridSpec(nx=4, ny=4, sx=1.0, sy=1.0, capacity=2)
    x = jnp.asarray([0.5, 0.5, 0.5, 0.5], jnp.float32)  # 4 agents, capacity 2
    y = jnp.asarray([0.5, 0.5, 0.5, 0.5], jnp.float32)
    _, overflow = G.build_table(gs, (0.0, 0.0), x, y, jnp.ones(4, bool))
    assert int(overflow) == 2


def test_dead_agents_excluded():
    ext = (4.0, 4.0)
    gs = G.make_grid(ext, (1.0, 1.0), 4, capacity_factor=50.0)
    x = np.asarray([1.0, 1.1], np.float32)
    y = np.asarray([1.0, 1.0], np.float32)
    alive = np.asarray([True, False])
    table, _ = G.build_table(gs, (0.0, 0.0), jnp.asarray(x), jnp.asarray(y), jnp.asarray(alive))
    # dead agent never appears in the table
    assert 1 not in set(np.asarray(table).ravel().tolist())

"""The paper's three simulation models: invariants + inversion equivalence."""

import numpy as np
import pytest

from repro.core import Engine
from repro.sims.fish import init_school, make_fish_sim
from repro.sims.predator import init_population, make_predator_sim, make_spawn_hook
from repro.sims.traffic import init_traffic, make_traffic_sim
from repro.sims.traffic_oracle import OracleParams, TrafficOracle, rmspe


def test_fish_school_coheres_and_moves():
    sim = make_fish_sim(world=(40.0, 10.0), omega=1.5, noise=0.02)
    # single informed direction (+x): informed individuals must entrain the
    # school (Couzin's information-transfer effect) → the school drifts +x
    st = init_school(
        sim, n=250, capacity=300, seed=0,
        directions=((1.0, 0.0), (1.0, 0.0)), informed_fraction=0.2,
    )
    eng = Engine(sim, n_agents_hint=250, cell_capacity=128)
    x0 = np.asarray(st.fields["x"])[np.asarray(st.alive)].mean()
    out, counts = eng.run(st, n_ticks=100, seed=0)
    assert int(counts[-1]) == 250
    alive = np.asarray(out.alive)
    hx = np.asarray(out.fields["hx"])[alive]
    hy = np.asarray(out.fields["hy"])[alive]
    norm = np.sqrt(hx**2 + hy**2)
    np.testing.assert_allclose(norm, 1.0, atol=1e-4)  # unit headings
    x1 = np.asarray(out.fields["x"])[alive].mean()
    assert x1 > x0 + 0.5, (x0, x1)  # informed minority steered the school


def test_fish_opposing_informed_groups_pull_apart():
    """Two informed subgroups pulling ±x (paper Fig. 7 setup): each
    informed subgroup must make headway in its preferred direction — the
    drift that changes the spatial distribution and exercises the load
    balancer."""
    sim = make_fish_sim(world=(60.0, 12.0), omega=3.0, noise=0.01)
    st = init_school(sim, n=200, capacity=256, seed=1, informed_fraction=0.4)
    eng = Engine(sim, n_agents_hint=200, cell_capacity=128)
    alive0 = np.asarray(st.alive)
    px = np.asarray(st.fields["px"])
    plus, minus = alive0 & (px > 0.5), alive0 & (px < -0.5)
    x0 = np.asarray(st.fields["x"])
    out, _ = eng.run(st, n_ticks=150, seed=0)
    x1 = np.asarray(out.fields["x"])
    gap0 = x0[plus].mean() - x0[minus].mean()
    gap1 = x1[plus].mean() - x1[minus].mean()
    assert gap1 > gap0 + 1.0, (gap0, gap1)


def test_traffic_invariants_and_flow():
    sim = make_traffic_sim(length=3000.0)
    st = init_traffic(sim, n=300, capacity=400, seed=0)
    eng = Engine(sim, n_agents_hint=300)
    out, counts = eng.run(st, n_ticks=50, seed=0)
    assert int(counts[-1]) == 300
    alive = np.asarray(out.alive)
    x = np.asarray(out.fields["x"])[alive]
    v = np.asarray(out.fields["v"])[alive]
    lane = np.asarray(out.fields["lane"])[alive]
    assert (x >= 0).all() and (x < 3000.0).all()      # wrapped
    assert (v >= 0).all() and (v <= 30.0 + 1e-5).all()  # physical speeds
    assert set(np.unique(lane)).issubset({0.0, 1.0, 2.0, 3.0})
    assert v.mean() > 5.0  # traffic flows


def test_traffic_statistics_match_handcoded_oracle():
    """Table 2 methodology: aggregate lane statistics RMSPE between the
    BRASIL program and the independent hand-coded simulator."""
    n, ticks, warmup = 240, 60, 20
    sim = make_traffic_sim(length=2000.0)
    st = init_traffic(sim, n=n, capacity=300, seed=0)
    eng = Engine(sim, n_agents_hint=n)

    # BRASIL side: average speed + lane occupancy over the run
    vs, lanes = [], []
    state = st
    for t in range(ticks):
        state, _ = eng.run(state, n_ticks=1, seed=0, t0=t)
        if t >= warmup:
            alive = np.asarray(state.alive)
            vs.append(np.asarray(state.fields["v"])[alive].mean())
            lanes.append(
                [
                    (np.abs(np.asarray(state.fields["lane"])[alive] - ln) < 0.5).sum()
                    for ln in range(4)
                ]
            )
    brasil_v = np.mean(vs)
    brasil_occ = np.mean(lanes, axis=0)

    # oracle side (same model, independent code + rng)
    p = OracleParams(length=2000.0)
    orc = TrafficOracle(p, seed=999)
    rs = np.random.RandomState(0)
    x = rs.uniform(0, p.length, n)
    lane = rs.randint(0, 4, n).astype(float)
    v = rs.uniform(10.0, 24.0, n)
    ovs, olanes = [], []
    for t in range(ticks):
        x, lane, v, _ = orc.step(x, lane, v)
        if t >= warmup:
            ovs.append(v.mean())
            olanes.append([(np.abs(lane - ln) < 0.5).sum() for ln in range(4)])
    oracle_v = np.mean(ovs)
    oracle_occ = np.mean(olanes, axis=0)

    assert rmspe([oracle_v], [brasil_v]) < 0.15, (oracle_v, brasil_v)
    assert rmspe(oracle_occ + 1, brasil_occ + 1) < 0.35, (oracle_occ, brasil_occ)


def test_predator_inversion_exact_equivalence():
    """Thm 2 end-to-end: scatter and compiler-inverted gather scripts give
    identical trajectories (same rand streams)."""
    st = None
    outs = []
    for inverted in (False, True):
        sim = make_predator_sim(world=(15.0, 15.0), inverted=inverted)
        if st is None:
            st = init_population(sim, n_prey=200, n_pred=20, capacity=300, seed=0)
        assert sim.plan.has_nonlocal is (not inverted)
        eng = Engine(sim, n_agents_hint=220)
        out, counts = eng.run(st, n_ticks=30, seed=0)
        outs.append((out, np.asarray(counts)))
    (a, ca), (b, cb) = outs
    assert np.array_equal(ca, cb)
    assert ca[-1] < ca[0]  # some prey died: the non-local effect does bite
    for k in a.fields:
        np.testing.assert_allclose(
            np.asarray(a.fields[k])[np.asarray(a.alive)],
            np.asarray(b.fields[k])[np.asarray(b.alive)],
            rtol=1e-5,
            atol=1e-5,
        )


def test_predator_spawn_hook_fills_free_slots():
    sim = make_predator_sim(world=(15.0, 15.0))
    st = init_population(sim, n_prey=50, n_pred=5, capacity=100, seed=0)
    # kill some prey so slots free up, boost health of others
    alive = np.asarray(st.alive).copy()
    health = np.asarray(st.fields["health"]).copy()
    alive[10:20] = False
    health[:10] = 99.0
    import jax.numpy as jnp

    from repro.core.agents import AgentState

    st = AgentState(
        alive=jnp.asarray(alive), oid=st.oid,
        fields=dict(st.fields, health=jnp.asarray(health)),
    )
    hook = make_spawn_hook(spawn_threshold=95.0)
    before = int(np.asarray(st.alive).sum())
    out = hook(st, tick=0)
    after = int(np.asarray(out.alive).sum())
    assert after == before + 10  # 10 healthy parents spawned into 10 free slots
    assert int(np.asarray(out.oid).max()) > int(np.asarray(st.oid).max())

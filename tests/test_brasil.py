"""BRASIL compiler: state-effect legality, algebraic rewrites, inversion."""

import numpy as np
import pytest

from repro.brasil import (
    AgentClass,
    BrasilError,
    Eff,
    Other,
    Param,
    Self,
    abs_,
    compile_agent,
    eliminate_dead_effects,
    fold_program_constants,
    invert_effects,
    rand_uniform,
    where,
)
from repro.brasil import ast as A


def _simple_class():
    F = AgentClass("F", position=("x", "y"), visibility=(1.0, 1.0))
    F.state("x", reach=0.5).state("y", reach=0.5).state("v")
    F.effect("e", "sum")
    return F


# ---- legality (the paper's read/write restrictions) -------------------------

def test_query_cannot_read_effects():
    F = _simple_class()
    F.emit("self", "e", Eff("e") + 1.0)
    F.update("x", Self("x"))
    with pytest.raises(BrasilError, match="write-only"):
        compile_agent(F)


def test_query_cannot_use_rand():
    F = _simple_class()
    F.emit("self", "e", rand_uniform())
    F.update("x", Self("x"))
    with pytest.raises(BrasilError, match="rand"):
        compile_agent(F)


def test_update_cannot_read_other():
    F = _simple_class()
    F.emit("self", "e", Other("v"))
    F.update("x", Other("x"))
    with pytest.raises(BrasilError, match="own fields"):
        compile_agent(F)


def test_unknown_fields_rejected():
    F = _simple_class()
    with pytest.raises(ValueError, match="unknown effect"):
        F.emit("self", "nope", 1.0)
    with pytest.raises(ValueError, match="unknown state"):
        F.update("nope", 1.0)


def test_min_by_requires_key():
    F = _simple_class()
    F.effect("m", "min_by", payload=["v"])
    with pytest.raises(ValueError, match="key"):
        F.emit("self", "m", 1.0)


def test_duplicate_declarations_rejected():
    F = _simple_class()
    with pytest.raises(ValueError):
        F.state("x")
    with pytest.raises(ValueError):
        F.effect("e")
    F.update("x", Self("x"))
    with pytest.raises(ValueError):
        F.update("x", Self("x"))


# ---- optimization rewrites ---------------------------------------------------

def test_constant_folding():
    F = _simple_class()
    F.emit("self", "e", (2.0 + 3.0) * Other("v"))
    F.update("x", Self("x") + (1.0 + 1.0))
    out = fold_program_constants(F)
    emit_expr = out.emits[0].value
    assert isinstance(emit_expr, A.BinOp)
    assert isinstance(emit_expr.a, A.Const) and emit_expr.a.value == 5.0


def test_dead_effect_elimination():
    F = _simple_class()
    F.effect("unused", "sum")
    F.emit("self", "e", Other("v"))
    F.emit("self", "unused", 1.0)
    F.update("x", Self("x") + Eff("e"))
    out = eliminate_dead_effects(F)
    assert "unused" not in out.effects
    assert len(out.emits) == 1


def test_inversion_swaps_roles_and_target():
    F = _simple_class()
    F.emit("other", "e", Self("v") - Other("v"), where=Other("v") > 0.0)
    F.update("x", Self("x") + Eff("e"))
    out = invert_effects(F)
    e = out.emits[0]
    assert e.target == "self"
    # value: Self("v") - Other("v") -> Other("v") - Self("v")
    assert e.value.a.role == A.OTHER and e.value.b.role == A.SELF
    assert e.where.a.role == A.SELF
    plan = compile_agent(out)
    assert plan.has_nonlocal is False


def test_inversion_is_involution_on_structure():
    F = _simple_class()
    F.emit("other", "e", Self("v"))
    F.update("x", Self("x") + Eff("e"))
    twice = invert_effects(invert_effects(F))
    # double inversion: target self→self (inversion only flips non-local)
    assert twice.emits[0].target == "self"


# ---- misc AST ---------------------------------------------------------------

def test_expression_type_errors():
    with pytest.raises(TypeError):
        Self("x") + "nope"


def test_where_and_calls_evaluate():
    env = A.EvalEnv({"x": np.asarray([1.0, -2.0])}, None, None, {})
    expr = where(Self("x") > 0.0, abs_(Self("x")), 0.0 - Self("x"))
    out = A.evaluate(expr, env)
    np.testing.assert_allclose(np.asarray(out), [1.0, 2.0])


def test_param_reference():
    env = A.EvalEnv({"x": np.asarray([1.0])}, None, None, {"k": 3.0})
    out = A.evaluate(Param("k") * Self("x"), env)
    np.testing.assert_allclose(np.asarray(out), [3.0])

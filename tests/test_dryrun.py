"""Dry-run machinery at CI scale: every family × shape-kind × both mesh
topologies lowers, compiles and analyzes on 8 fake devices; plus unit
tests for the HLO collective parser and sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_distribute import run_helper


def test_dryrun_all_families_small_meshes():
    res = run_helper("dryrun_small.py", [], 8, timeout=1500)
    assert res["ok"], res["fails"]
    assert res["n"] == 30  # 5 archs × 3 shapes × 2 meshes


def test_collective_parser():
    from repro.dist.hlo_analysis import collective_bytes

    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[16]{0} all-reduce(%y), to_apply=%add
  %rs = (f32[4]{0}, f32[4]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[2]{0} collective-permute(%c), source_target_pairs={{0,1}}
  %notacoll = f32[999]{0} add(%p, %q)
  %ag2 = bf16[4,4]{1,0} all-gather-start(%z)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2 + 4 * 4 * 2
    assert out["all-reduce"] == 16 * 4
    assert out["reduce-scatter"] == 4 * 4 + 4 * 4
    assert out["collective-permute"] == 2 * 4
    assert out["all-to-all"] == 0


def test_roofline_terms_and_dominance():
    from repro.dist.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline

    r = Roofline(
        flops_per_device=197e12,      # exactly 1 s of compute
        bytes_per_device=819e9 / 2,   # 0.5 s of HBM
        coll_bytes_per_device=50e9 / 4,  # 0.25 s of ICI
        coll_breakdown={}, n_devices=256,
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(0.5)
    assert r.t_collective == pytest.approx(0.25)
    assert r.dominant == "compute"
    assert r.t_total_overlap == pytest.approx(1.0)


def test_param_sharding_rules_divisibility_guard():
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import MeshAxes, param_pspec

    class FakeMesh:  # param_pspec only reads mesh.shape sizes
        shape = {"data": 2, "model": 2}

    mesh = FakeMesh()
    axes = MeshAxes(fsdp=("data",), tensor="model", batch=("data",))

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    path = (jax.tree_util.DictKey("wq"),)
    # divisible: both dims shard
    spec = param_pspec(path, Leaf((8, 8)), mesh, axes, stacked=False)
    assert spec == P(("data",), ("model",))
    # odd dims: fall back to replication per-dim
    spec = param_pspec(path, Leaf((7, 8)), mesh, axes, stacked=False)
    assert spec == P(None, ("model",))
    # stacked layer dim stays replicated
    path2 = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("wq"))
    spec = param_pspec(path2, Leaf((4, 8, 8)), mesh, axes, stacked=True)
    assert spec == P(None, ("data",), ("model",))


def test_input_specs_cover_every_cell():
    from repro.configs.base import SHAPES, all_archs, get_arch, supports
    from repro.launch.dryrun_lib import input_specs

    n_cells = 0
    n_skips = 0
    for arch in all_archs():
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            ok, why = supports(cfg, shape)
            n_cells += 1
            if not ok:
                n_skips += 1
                continue
            specs = input_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
            if shape.kind != "decode":
                assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
    assert n_cells == 40
    assert n_skips == 6  # documented full-attention long_500k skips

"""Training stack: optimizer math, schedules, chunked CE, microbatching,
gradient compression, data pipeline determinism, checkpoint restart."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, schedule
from repro.train.train_step import (
    TrainConfig,
    chunked_ce,
    cross_entropy,
    init_train_state,
    make_train_step,
)


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9, warmup_steps=0, total_steps=1,
                      min_lr_ratio=1.0)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.1, 0.2])}
    opt = adamw_init(params)
    new_params, opt, _ = adamw_update(cfg, grads, opt, jnp.float32)
    # manual AdamW step 1
    g = np.asarray([0.1, 0.2])
    m = 0.1 * g
    v = 0.01 * g * g
    mhat = m / 0.1
    vhat = v / 0.01
    expect = np.asarray([1.0, -2.0]) - 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_params["w"]), expect, rtol=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    end = float(schedule(cfg, jnp.asarray(110)))
    assert abs(end - 0.1) < 1e-6


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 100.0)}
    opt = adamw_init(params)
    _, _, metrics = adamw_update(cfg, grads, opt, jnp.float32)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


def test_chunked_ce_matches_plain():
    rs = np.random.RandomState(0)
    b, s, d, v = 2, 64, 16, 50
    hidden = jnp.asarray(rs.randn(b, s, d).astype(np.float32))
    head = jnp.asarray(rs.randn(d, v).astype(np.float32))
    labels = jnp.asarray(rs.randint(0, v, (b, s)))
    plain = cross_entropy(hidden @ head, labels)
    chunked = chunked_ce(hidden, head, labels, n_chunks=8)
    np.testing.assert_allclose(float(plain), float(chunked), rtol=1e-5)
    # grads agree too
    g1 = jax.grad(lambda h: cross_entropy(h @ head, labels))(hidden)
    g2 = jax.grad(lambda h: chunked_ce(h, head, labels, n_chunks=8))(hidden)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_microbatched_step_matches_full_batch():
    cfg = reduced(get_arch("granite-8b"))
    model = build_model(cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab),
    }
    outs = {}
    for mb in (1, 4):
        tc = TrainConfig(optimizer=AdamWConfig(warmup_steps=0), microbatches=mb)
        state = init_train_state(model, jax.random.PRNGKey(0), tc)
        step = jax.jit(make_train_step(model, tc))
        state, metrics = step(state, batch)
        outs[mb] = (state, float(metrics["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    w1 = jax.tree.leaves(outs[1][0].params)
    w4 = jax.tree.leaves(outs[4][0].params)
    for a, b_ in zip(w1, w4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-3)


def test_training_reduces_loss():
    cfg = reduced(get_arch("granite-8b"), n_layers=2, d_model=64)
    model = build_model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60))
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    from repro.data.pipeline import DataConfig, SyntheticTokens

    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, (
        losses[:5], losses[-5:]
    )


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_error_bounded():
    from repro.train.compression import compress, decompress

    rs = np.random.RandomState(0)
    tree = {"a": jnp.asarray(rs.randn(64).astype(np.float32)),
            "b": jnp.asarray(rs.randn(8, 8).astype(np.float32) * 10)}
    q, scales, err = compress(tree)
    out = decompress(q, scales)
    for k in tree:
        scale = float(jnp.max(jnp.abs(tree[k]))) / 127.0
        assert np.abs(np.asarray(out[k]) - np.asarray(tree[k])).max() <= scale * 0.51
        # error feedback holds the residual exactly
        np.testing.assert_allclose(
            np.asarray(err[k]), np.asarray(tree[k]) - np.asarray(out[k]), atol=1e-6
        )


def test_error_feedback_drives_mean_error_to_zero():
    """With error feedback, repeated compression of a CONSTANT gradient
    transmits the right mean value over time (bias-free)."""
    from repro.train.compression import compress, decompress

    g = jnp.asarray(np.linspace(-1, 1, 32).astype(np.float32))
    err = jnp.zeros_like(g)
    sent = []
    for _ in range(50):
        q, s, err = compress({"g": g + err})
        out = decompress(q, s)["g"]
        err = err["g"] if isinstance(err, dict) else err
        sent.append(np.asarray(out))
    mean_sent = np.mean(sent, axis=0)
    np.testing.assert_allclose(mean_sent, np.asarray(g), atol=2e-2)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, SyntheticTokens

    cfg = DataConfig(vocab=100, seq_len=32, global_batch=8, seed=3)
    a = SyntheticTokens(cfg, shard=0, n_shards=2).batch_at(5)
    b = SyntheticTokens(cfg, shard=0, n_shards=2).batch_at(5)
    c = SyntheticTokens(cfg, shard=1, n_shards=2).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])      # disjoint shards
    assert a["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_train_checkpoint_restart(tmp_path):
    from repro.launch.ckpt_train import TrainCheckpointManager

    cfg = reduced(get_arch("granite-8b"))
    model = build_model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig())
    state = init_train_state(model, jax.random.PRNGKey(0), tc)
    mgr = TrainCheckpointManager(str(tmp_path))
    mgr.save(state, 42)
    template = init_train_state(model, jax.random.PRNGKey(1), tc)  # different init
    restored, step = mgr.restore(template)
    assert step == 42
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Per-arch smoke tests (reduced configs, CPU): forward shapes/NaNs, one
train step, and exact prefill+decode consistency against the full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch, reduced
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, key=KEY, s=S):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, s), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, max(s // 4, 4), cfg.d_model), jnp.float32)
        return {"frames": frames, "tokens": tokens}
    return {"tokens": tokens}


@pytest.mark.parametrize("name", all_archs())
def test_forward_shapes_and_finiteness(name):
    cfg = reduced(get_arch(name))
    model = build_model(cfg)
    params = model.init(KEY)
    logits = jax.jit(model.forward)(params, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", all_archs())
def test_one_train_step_reduces_loss_direction(name):
    """One SGD step on the CE loss must produce finite grads for every leaf."""
    cfg = reduced(get_arch(name))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    labels = batch["tokens"]

    def loss_fn(p):
        logits = model.forward(p, batch)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    # not all grads are zero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("name", all_archs())
def test_prefill_decode_matches_forward(name):
    cfg = reduced(get_arch(name))
    model = build_model(cfg)
    params = model.init(KEY)
    s_pre, n_dec = 16, 6
    s = s_pre + n_dec
    batch = _batch(cfg, s=s)
    tokens = batch["tokens"]
    full = model.forward(params, batch)
    if cfg.family == "encdec":
        pre = {"frames": batch["frames"], "tokens": tokens[:, :s_pre]}
    else:
        pre = tokens[:, :s_pre]
    logits_p, cache, pos = model.prefill(params, pre, s)
    errs = [np.abs(np.asarray(logits_p) - np.asarray(full[:, s_pre - 1])).max()]
    step = jax.jit(model.decode_step)
    for t in range(n_dec):
        logits_d, cache = step(params, cache, tokens[:, s_pre + t], pos)
        pos = pos + 1
        errs.append(np.abs(np.asarray(logits_d) - np.asarray(full[:, s_pre + t])).max())
    assert max(errs) < 2e-3, errs


def test_scan_matches_unrolled_layers():
    cfg = reduced(get_arch("granite-8b"))
    import dataclasses

    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    model_a = build_model(cfg)
    model_b = build_model(cfg_scan)
    params = model_a.init(KEY)
    batch = _batch(cfg)
    la = model_a.forward(params, batch)
    lb = model_b.forward(params, batch)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)


def test_swa_tightens_attention():
    """A sliding window must change logits vs full attention on long seqs
    (and equal them when window >= seq)."""
    import dataclasses

    cfg = reduced(get_arch("h2o-danube-3-4b"))
    model_win = build_model(dataclasses.replace(cfg, window=8))
    model_big = build_model(dataclasses.replace(cfg, window=None))
    model_huge = build_model(dataclasses.replace(cfg, window=4 * S))
    params = model_win.init(KEY)
    batch = _batch(cfg)
    lw = model_win.forward(params, batch)
    lb = model_big.forward(params, batch)
    lh = model_huge.forward(params, batch)
    assert np.abs(np.asarray(lw) - np.asarray(lb)).max() > 1e-3
    np.testing.assert_allclose(np.asarray(lh), np.asarray(lb), atol=1e-4)


def test_param_count_matches_actual():
    for name in ("granite-8b", "qwen2-7b", "mixtral-8x22b"):
        cfg = get_arch(name)
        est = cfg.param_count()
        # sanity bands from the model names
        expected = {"granite-8b": 8e9, "qwen2-7b": 7.6e9, "mixtral-8x22b": 140e9}[name]
        assert 0.5 * expected < est < 1.6 * expected, (name, est, expected)

"""1-D load balancer: equal-cost inversion, migration estimate, decision."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import loadbalance as LB


def test_equal_cost_bounds_balances_skewed_load():
    bounds = np.asarray([0.0, 25.0, 50.0, 75.0, 100.0])
    costs = np.asarray([100.0, 0.0, 0.0, 0.0])
    new = LB.equal_cost_bounds(bounds, costs, min_width=1.0)
    # all load is in slab 0 → new boundaries subdivide [0, 25)
    assert new[0] == 0.0 and new[-1] == 100.0
    assert np.all(np.diff(new) >= 1.0 - 1e-9)
    assert new[1] < 25.0 and new[2] < 26.0 and new[3] < 27.0


@given(
    seed=st.integers(0, 10_000),
    p=st.integers(2, 12),
)
@settings(max_examples=40, deadline=None)
def test_equal_cost_bounds_monotone_and_min_width(seed, p):
    rs = np.random.RandomState(seed)
    edges = np.concatenate([[0.0], np.sort(rs.uniform(1, 99, p - 1)), [100.0]])
    costs = rs.uniform(0, 10, p)
    min_w = 0.5
    new = LB.equal_cost_bounds(edges, costs, min_width=min_w)
    assert new[0] == edges[0] and new[-1] == edges[-1]
    assert np.all(np.diff(new) >= min_w - 1e-9)


def test_migration_estimate_zero_when_unchanged():
    bounds = np.asarray([0.0, 50.0, 100.0])
    counts = np.asarray([10.0, 10.0])
    assert LB.estimate_migration(bounds, bounds, counts) == 0.0


def test_decision_balanced_load_no_rebalance():
    bounds = np.linspace(0, 100, 5)
    counts = np.asarray([10.0, 10.5, 9.5, 10.0])
    d = LB.decide(bounds, counts, min_width=1.0)
    assert not d.rebalance
    assert d.imbalance < 1.1


def test_decision_skewed_load_rebalances():
    bounds = np.linspace(0, 100, 5)
    counts = np.asarray([100.0, 2.0, 2.0, 2.0])
    d = LB.decide(bounds, counts, min_width=1.0)
    assert d.rebalance
    assert d.predicted_imbalance < d.imbalance


def test_pair_weight_prefers_denser_slabs():
    bounds = np.asarray([0.0, 50.0, 100.0])
    counts = np.asarray([20.0, 20.0])
    flat = LB.slab_costs(counts, np.diff(bounds), pair_weight=0.0)
    quad = LB.slab_costs(counts, np.diff(bounds), pair_weight=1.0)
    assert np.all(quad > flat)

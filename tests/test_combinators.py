"""Combinator laws: the state-effect pattern requires every effect
combinator to be decomposable and order-independent (paper §2.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import combinators as C

finite = st.floats(
    min_value=-1e6,
    max_value=1e6,
    allow_nan=False,
    allow_infinity=False,
    allow_subnormal=False,  # XLA CPU flushes denormals to zero
    width=32,
)


@pytest.mark.parametrize("name", ["sum", "min", "max"])
@given(a=finite, b=finite, c=finite)
@settings(max_examples=50, deadline=None)
def test_combine_commutative_associative(name, a, b, c):
    comb = C.get(name)
    a, b, c = (jnp.float32(v) for v in (a, b, c))
    ab = comb.combine(a, b)
    ba = comb.combine(b, a)
    np.testing.assert_allclose(np.asarray(ab), np.asarray(ba), rtol=1e-6)
    left = comb.combine(comb.combine(a, b), c)
    right = comb.combine(a, comb.combine(b, c))
    if name == "sum":
        np.testing.assert_allclose(np.asarray(left), np.asarray(right), rtol=1e-4, atol=1e-2)
    else:
        np.testing.assert_allclose(np.asarray(left), np.asarray(right), rtol=1e-6)


@pytest.mark.parametrize("name", ["sum", "min", "max"])
@given(a=finite)
@settings(max_examples=25, deadline=None)
def test_identity_element(name, a):
    comb = C.get(name)
    ident = comb.identity((), jnp.float32)
    out = comb.combine(jnp.float32(a), ident)
    np.testing.assert_allclose(np.asarray(out), np.float32(a), rtol=1e-6)


@pytest.mark.parametrize("name", ["sum", "min", "max"])
def test_reduce_matches_pairwise_combine(name):
    comb = C.get(name)
    rs = np.random.RandomState(0)
    contrib = jnp.asarray(rs.randn(4, 7).astype(np.float32))
    mask = jnp.asarray(rs.rand(4, 7) > 0.3)
    red = comb.reduce(contrib, mask, axis=1)
    for i in range(4):
        acc = comb.identity((), jnp.float32)
        for j in range(7):
            if bool(mask[i, j]):
                acc = comb.combine(acc, contrib[i, j])
        np.testing.assert_allclose(np.asarray(red[i]), np.asarray(acc), rtol=1e-5)


@pytest.mark.parametrize("name", ["sum", "min", "max"])
def test_scatter_matches_serial(name):
    comb = C.get(name)
    rs = np.random.RandomState(1)
    n, k = 5, 12
    target = np.asarray(comb.identity((n,), jnp.float32))
    idx = jnp.asarray(rs.randint(0, n, (3, k)).astype(np.int32))
    contrib = jnp.asarray(rs.randn(3, k).astype(np.float32))
    mask = jnp.asarray(rs.rand(3, k) > 0.4)
    out = comb.scatter(jnp.asarray(target), idx, contrib, mask)
    ref = target.copy()
    for i in range(3):
        for j in range(k):
            if bool(mask[i, j]):
                t = int(idx[i, j])
                ref[t] = np.asarray(
                    comb.combine(jnp.float32(ref[t]), contrib[i, j])
                )
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)


def test_min_by_selects_argmin_record():
    comb = C.MIN_BY
    key = jnp.asarray([[3.0, 1.0, 2.0], [5.0, 9.0, 7.0]])
    pay = jnp.asarray([[30.0, 10.0, 20.0], [50.0, 90.0, 70.0]])
    mask = jnp.asarray([[True, True, True], [True, False, True]])
    red = comb.reduce({"key": key, "v": pay}, mask, axis=1)
    np.testing.assert_allclose(np.asarray(red["key"]), [1.0, 5.0])
    np.testing.assert_allclose(np.asarray(red["v"]), [10.0, 50.0])


def test_min_by_empty_returns_identity_key():
    comb = C.MIN_BY
    key = jnp.asarray([[3.0, 1.0]])
    pay = jnp.asarray([[30.0, 10.0]])
    mask = jnp.zeros((1, 2), bool)
    red = comb.reduce({"key": key, "v": pay}, mask, axis=1)
    assert float(red["key"][0]) > 1e30


def test_max_by_combine_keeps_larger_key():
    comb = C.MAX_BY
    a = {"key": jnp.float32(2.0), "v": jnp.float32(20.0)}
    b = {"key": jnp.float32(5.0), "v": jnp.float32(50.0)}
    out = comb.combine(a, b)
    assert float(out["key"]) == 5.0 and float(out["v"]) == 50.0


def test_argopt_scatter_raises():
    with pytest.raises(NotImplementedError):
        C.MIN_BY.scatter(None, None, None, None)

"""Distributed runtime == single-device oracle, via subprocesses with fake
device counts (the main conftest deliberately keeps 1 device)."""

import json
import os
import subprocess
import sys

import pytest

HELPERS = os.path.join(os.path.dirname(__file__), "helpers")


def run_helper(script: str, args: list[str], n_dev: int, timeout=600):
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
        PYTHONPATH=HELPERS,
    )
    out = subprocess.run(
        [sys.executable, os.path.join(HELPERS, script), *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert out.returncode == 0, f"helper failed:\n{out.stdout}\n{out.stderr}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize(
    "scenario,n_dev",
    [
        ("fish_local", 4),       # local effects: single reduce pass
        ("fish_nonlocal", 4),    # non-local: map-reduce-reduce
        ("fish_nonlocal", 8),
        ("fish_tp", 4),          # forced two-pass on a local program
        ("traffic_periodic", 4), # periodic ring (circular road)
        ("predator", 4),         # deaths + min_by under distribution
    ],
)
def test_distributed_matches_single_device(scenario, n_dev):
    res = run_helper("dist_check.py", [scenario, str(n_dev)], n_dev)
    assert res["ok"], res
    assert res["n_dev"] == n_dev
    assert all(v == 0 for v in res["overflows"].values()), res

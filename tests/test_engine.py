"""Single-node engine: spatial-join correctness (grid == brute force) and
tick semantics, using the paper's Fig. 2 fish program."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.brasil import (
    AgentClass,
    Eff,
    Other,
    Self,
    abs_,
    invert_effects,
)
from repro.core import Engine, Simulation, uniform_population


def fig2_fish(vis=1.0):
    """The paper's Fig. 2 class (deterministic variant for exact replay)."""
    F = AgentClass("Fish", position=("x", "y"), visibility=(vis, vis))
    F.state("x", reach=0.1).state("y", reach=0.1).state("vx").state("vy")
    F.effect("avoidx", "sum").effect("avoidy", "sum").effect("count", "sum")
    eps = 1e-1
    F.emit("other", "avoidx", 1.0 / (abs_(Self("x") - Other("x")) + eps))
    F.emit("other", "avoidy", 1.0 / (abs_(Self("y") - Other("y")) + eps))
    F.emit("other", "count", 1.0)
    F.update("x", Self("x") + Self("vx"))
    F.update("y", Self("y") + Self("vy"))
    F.update("vx", Self("vx") * 0.95 + Eff("avoidx") / (Eff("count") + 1.0) * 0.01)
    F.update("vy", Self("vy") * 0.95 + Eff("avoidy") / (Eff("count") + 1.0) * 0.01)
    return F


@given(seed=st.integers(0, 1000), n=st.integers(5, 80))
@settings(max_examples=10, deadline=None)
def test_grid_join_matches_bruteforce(seed, n):
    sim = Simulation.build(fig2_fish(), world_lo=(0, 0), world_hi=(12, 9))
    state = uniform_population(sim, n, capacity=n + 8, seed=seed)
    eg = Engine(sim, n_agents_hint=n, index="grid").query_effects(state)
    eb = Engine(sim, n_agents_hint=n, index="brute").query_effects(state)
    for k in eg:
        np.testing.assert_allclose(
            np.asarray(eg[k]), np.asarray(eb[k]), rtol=1e-5, atol=1e-5
        )


def test_effect_inversion_query_equivalence():
    F = fig2_fish()
    sim = Simulation.build(F, world_lo=(0, 0), world_hi=(12, 9))
    simi = Simulation.build(invert_effects(F), world_lo=(0, 0), world_hi=(12, 9))
    state = uniform_population(sim, 60, capacity=64, seed=7)
    e = Engine(sim, n_agents_hint=60).query_effects(state)
    ei = Engine(simi, n_agents_hint=60).query_effects(state)
    for k in e:
        np.testing.assert_allclose(
            np.asarray(e[k]), np.asarray(ei[k]), rtol=1e-4, atol=1e-4
        )


def test_ticks_preserve_population_and_finiteness():
    sim = Simulation.build(fig2_fish(), world_lo=(0, 0), world_hi=(12, 9))
    state = uniform_population(sim, 50, capacity=64, seed=3)
    out, counts = Engine(sim, n_agents_hint=50).run(state, n_ticks=25, seed=0)
    assert np.asarray(counts).tolist() == [50] * 25
    for k, v in out.fields.items():
        assert np.isfinite(np.asarray(v)[np.asarray(out.alive)]).all(), k


def test_reach_crop_enforced():
    """#range: no state may move more than its reach bound per tick."""
    F = AgentClass("A", position=("x", "y"), visibility=(1.0, 1.0))
    F.state("x", reach=0.25).state("y", reach=0.25)
    F.effect("e", "sum")
    F.emit("self", "e", 1.0)
    F.update("x", Self("x") + 5.0)  # tries to jump far
    F.update("y", Self("y"))
    sim = Simulation.build(F, world_lo=(0, 0), world_hi=(10, 10))
    state = uniform_population(sim, 20, capacity=24, seed=0)
    x0 = np.asarray(state.fields["x"]).copy()
    out, _ = Engine(sim, n_agents_hint=20).run(state, n_ticks=1, seed=0)
    x1 = np.asarray(out.fields["x"])
    alive = np.asarray(out.alive)
    np.testing.assert_allclose(x1[alive] - x0[alive], 0.25, atol=1e-5)


def test_visibility_limits_interaction():
    """Weak-reference semantics (Thm 1): agents outside ρ contribute nothing."""
    F = AgentClass("A", position=("x", "y"), visibility=(1.0, 1.0))
    F.state("x").state("y")
    F.effect("cnt", "sum")
    F.emit("self", "cnt", 1.0)
    F.update("x", Self("x"))
    F.update("y", Self("y"))
    sim = Simulation.build(F, world_lo=(0, 0), world_hi=(10, 10))
    state = sim.init_population(
        4,
        oid=np.arange(3),
        x=np.asarray([1.0, 1.5, 9.0], np.float32),
        y=np.asarray([1.0, 1.0, 1.0], np.float32),
    )
    eff = Engine(sim, n_agents_hint=3).query_effects(state)
    assert np.asarray(eff["cnt"])[:3].tolist() == [1.0, 1.0, 0.0]


def test_dead_agents_do_not_interact():
    F = AgentClass("A", position=("x", "y"), visibility=(2.0, 2.0))
    F.state("x").state("y").state("hp")
    F.effect("cnt", "sum")
    F.emit("self", "cnt", 1.0)
    F.update("x", Self("x"))
    F.update("y", Self("y"))
    F.update("hp", Self("hp") - 1.0)
    F.kill(Self("hp") <= 1.0)
    sim = Simulation.build(F, world_lo=(0, 0), world_hi=(10, 10))
    state = sim.init_population(
        4, oid=np.arange(2),
        x=np.asarray([1.0, 1.5], np.float32),
        y=np.asarray([1.0, 1.0], np.float32),
        hp=np.asarray([1.0, 5.0], np.float32),
    )
    eng = Engine(sim, n_agents_hint=2)
    out, counts = eng.run(state, n_ticks=1, seed=0)
    assert int(counts[-1]) == 1  # first agent died
    eff = eng.query_effects(out)
    assert float(np.asarray(eff["cnt"])[1]) == 0.0  # survivor sees nobody

"""CheckpointManager: atomicity, GC, torn-write fallback (single device)."""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.agents import AgentState
from repro.core.checkpoint import CheckpointManager


def _state(n=16, seed=0):
    rs = np.random.RandomState(seed)
    return AgentState(
        alive=jnp.asarray(rs.rand(n) > 0.3),
        oid=jnp.arange(n, dtype=jnp.int32),
        fields={
            "x": jnp.asarray(rs.randn(n).astype(np.float32)),
            "h": jnp.asarray(rs.randn(n, 2).astype(np.float32)),
        },
    )


def _assert_equal(a: AgentState, b: AgentState):
    np.testing.assert_array_equal(np.asarray(a.alive), np.asarray(b.alive))
    np.testing.assert_array_equal(np.asarray(a.oid), np.asarray(b.oid))
    for k in a.fields:
        np.testing.assert_array_equal(np.asarray(a.fields[k]), np.asarray(b.fields[k]))


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    st = _state()
    mgr.save(10, st, meta={"tick": 10, "epoch": 1, "bounds": [0.0, 1.0]})
    got, meta = mgr.restore()
    _assert_equal(st, got)
    assert meta["tick"] == 10 and meta["epoch"] == 1


def test_async_write_and_latest_selection(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    for step in (5, 10, 15):
        mgr.save(step, _state(seed=step), meta={"tick": step, "epoch": step // 5})
    mgr.wait()
    got, meta = mgr.restore()
    assert meta["tick"] == 15
    _assert_equal(_state(seed=15), got)


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for step in range(5):
        mgr.save(step, _state(seed=step), meta={"tick": step, "epoch": step})
    assert mgr.list_steps() == [3, 4]


def test_torn_write_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, _state(seed=1), meta={"tick": 1, "epoch": 1})
    mgr.save(2, _state(seed=2), meta={"tick": 2, "epoch": 2})
    # corrupt the newest snapshot (torn write)
    with open(os.path.join(str(tmp_path), "ckpt_0000000002.npz"), "wb") as f:
        f.write(b"garbage")
    got, meta = mgr.restore()
    assert meta["tick"] == 1
    _assert_equal(_state(seed=1), got)


def test_restore_empty_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()

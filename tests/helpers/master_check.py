"""Subprocess helper for master-level features.

Scenarios:
  checkpoint_resume <P>  — run 4 epochs w/ checkpoints; "crash"; restore at
                           epoch 2 and re-run; final states must match.
  elastic <P>            — checkpoint on P devices is restored and continued
                           on P/2 devices (mesh-agnostic snapshot).
  loadbalance <P>        — drifting fish school: with LB the per-slab
                           imbalance must stay below the no-LB run.
Prints JSON on the last line.
"""

import json
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np  # noqa: E402


def by_oid(st):
    alive = np.asarray(st.alive)
    oid = np.asarray(st.oid)[alive]
    out = {k: np.asarray(v)[alive] for k, v in st.fields.items()}
    order = np.argsort(oid)
    return oid[order], {k: v[order] for k, v in out.items()}


def states_equal(a, b, rtol=3e-4, atol=3e-5):
    oa, fa = by_oid(a)
    ob, fb = by_oid(b)
    if not np.array_equal(oa, ob):
        return False
    return all(np.allclose(fa[k], fb[k], rtol=rtol, atol=atol) for k in fa)


def build(n=400):
    from tests_fixtures import fig2_fish_sim

    return fig2_fish_sim(nonlocal_=True, world=(40.0, 10.0), n=n)


def main():
    scenario = sys.argv[1]

    from repro.core.distribute import DistEngine
    from repro.core.master import Master, MasterConfig

    tmp = tempfile.mkdtemp(prefix="brace_ckpt_")
    try:
        if scenario == "checkpoint_resume":
            sim, state0, n = build()
            eng = DistEngine(sim, n_agents_hint=n)
            cfg = MasterConfig(
                ticks_per_epoch=5, checkpoint_every=1, checkpoint_dir=tmp,
                load_balance=False, seed=0,
            )
            m1 = Master(eng, cfg)
            st = m1.start(state0)
            st, _ = m1.run(st, n_epochs=4)
            final_ref = eng.gather(st)

            # "crash": new master, restore from the epoch-2 checkpoint
            # (explicit step — the GC may have dropped older ones),
            # re-execute the remaining epochs
            step2 = 2 * cfg.ticks_per_epoch
            m2 = Master(DistEngine(sim, n_agents_hint=n), cfg)
            st2 = m2.restore_from_checkpoint(step2)
            assert m2.epoch == 2, m2.epoch
            st2, _ = m2.run(st2, n_epochs=2)
            final_re = m2.engine.gather(st2)
            ok = states_equal(final_ref, final_re)
            print(json.dumps({"ok": bool(ok), "restored_step": step2}))

        elif scenario == "elastic":
            import jax

            sim, state0, n = build()
            all_devs = jax.devices()
            p_full = len(all_devs)
            mesh_full = jax.make_mesh(
                (p_full,), ("space",),
                axis_types=(jax.sharding.AxisType.Auto,),
            )
            eng = DistEngine(sim, n_agents_hint=n, mesh=mesh_full)
            cfg = MasterConfig(
                ticks_per_epoch=5, checkpoint_every=1, checkpoint_dir=tmp,
                load_balance=False, seed=0,
            )
            m1 = Master(eng, cfg)
            st = m1.start(state0)
            st, _ = m1.run(st, n_epochs=2)

            # reference: continue on the full mesh
            st_ref, _ = m1.run(st, n_epochs=2)
            ref = eng.gather(st_ref)

            # elastic: restore the same checkpoint on HALF the devices
            mesh_half = jax.make_mesh(
                (p_full // 2,), ("space",),
                axis_types=(jax.sharding.AxisType.Auto,),
                devices=all_devs[: p_full // 2],
            )
            eng2 = DistEngine(sim, n_agents_hint=n, mesh=mesh_half)
            m2 = Master(eng2, cfg)
            st2 = m2.restore_from_checkpoint(2 * cfg.ticks_per_epoch)
            assert m2.epoch == 2
            st2, _ = m2.run(st2, n_epochs=2)
            got = eng2.gather(st2)
            ok = states_equal(ref, got)
            print(json.dumps({"ok": bool(ok), "p_full": p_full}))

        elif scenario == "loadbalance":
            from repro.sims.fish import init_school, make_fish_sim

            n = 600
            sim = make_fish_sim(world=(60.0, 12.0))
            state0 = init_school(
                sim, n=n, capacity=2 * n, seed=0, informed_fraction=0.2
            )

            def run(lb: bool):
                # fish school clusters way past uniform density → explicit
                # cell capacity (overflow is checked by the master)
                eng = DistEngine(
                    sim, n_agents_hint=n, capacity_factor=8.0, cell_capacity=192
                )
                m = Master(
                    eng,
                    MasterConfig(
                        ticks_per_epoch=20, checkpoint_every=0,
                        load_balance=lb, lb_imbalance_threshold=1.15, seed=0,
                    ),
                )
                st = m.start(state0)
                imb = []
                for _ in range(6):
                    st, rep = m.run_epoch(st)
                    imb.append(rep.imbalance)
                return imb

            imb_lb = run(True)
            imb_no = run(False)
            # with LB, late-epoch imbalance must be clearly smaller
            ok = np.mean(imb_lb[-3:]) < np.mean(imb_no[-3:])
            print(json.dumps({"ok": bool(ok), "lb": imb_lb, "no_lb": imb_no}))
        else:
            raise SystemExit(f"unknown scenario {scenario}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Subprocess helper: verify distributed == single-device on N fake devices.

Run as:  XLA_FLAGS=--xla_force_host_platform_device_count=<P> \
         python tests/helpers/dist_check.py <scenario> <P>
Prints JSON {"ok": bool, ...} on the last line.
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np  # noqa: E402


def by_oid(st):
    alive = np.asarray(st.alive)
    oid = np.asarray(st.oid)[alive]
    out = {k: np.asarray(v)[alive] for k, v in st.fields.items()}
    order = np.argsort(oid)
    return oid[order], {k: v[order] for k, v in out.items()}


def compare(ref, got, rtol=3e-4, atol=3e-5):
    oid_r, f_r = by_oid(ref)
    oid_d, f_d = by_oid(got)
    if not np.array_equal(oid_r, oid_d):
        return False, f"population mismatch {len(oid_r)} vs {len(oid_d)}"
    for k in f_r:
        if not np.allclose(f_r[k], f_d[k], rtol=rtol, atol=atol):
            err = np.abs(f_r[k] - f_d[k]).max()
            return False, f"field {k} max err {err}"
    return True, ""


def main():
    scenario = sys.argv[1]
    import jax

    from repro.core import Engine
    from repro.core.distribute import DistEngine

    n_dev = jax.device_count()
    ticks = 12

    if scenario in ("fish_local", "fish_nonlocal", "fish_tp"):
        from tests_fixtures import fig2_fish_sim

        sim, state, n = fig2_fish_sim(
            nonlocal_=scenario != "fish_local", world=(40.0, 10.0), n=400
        )
    elif scenario == "traffic_periodic":
        from repro.sims.traffic import init_traffic, make_traffic_sim

        sim = make_traffic_sim(length=4000.0)
        n = 300
        state = init_traffic(sim, n=n, capacity=400, seed=0)
    elif scenario == "predator":
        from repro.sims.predator import init_population, make_predator_sim

        sim = make_predator_sim(world=(30.0, 10.0))
        n = 300
        state = init_population(sim, n_prey=270, n_pred=30, capacity=400, seed=0)
    else:
        raise SystemExit(f"unknown scenario {scenario}")

    eng = Engine(sim, n_agents_hint=n, index="grid")
    ref, _ = eng.run(state, n_ticks=ticks, seed=0)

    deng = DistEngine(
        sim, n_agents_hint=n, two_pass=True if scenario == "fish_tp" else None
    )
    bounds = deng.uniform_bounds()
    dstate = deng.distribute(state, bounds)
    dstate, stats = deng.run_epoch(dstate, bounds, n_ticks=ticks, seed=0)
    got = deng.gather(dstate)

    ok, msg = compare(ref, got)
    overflows = {
        k: int(np.asarray(v).sum()) for k, v in stats.items() if "overflow" in k
    }
    ok = ok and all(v == 0 for v in overflows.values())
    print(json.dumps({"ok": ok, "msg": msg, "n_dev": n_dev, "overflows": overflows}))


if __name__ == "__main__":
    main()

"""Shared fixtures for subprocess helpers."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np  # noqa: E402

from repro.brasil import AgentClass, Eff, Other, Self, abs_  # noqa: E402
from repro.core import Simulation, uniform_population  # noqa: E402


def fig2_fish_sim(nonlocal_: bool = True, world=(40.0, 10.0), n: int = 400):
    """Deterministic Fig. 2 fish; non-local or pre-inverted local variant."""
    F = AgentClass("Fish", position=("x", "y"), visibility=(1.0, 1.0))
    F.state("x", reach=0.1).state("y", reach=0.1).state("vx").state("vy")
    F.effect("avoidx", "sum").effect("avoidy", "sum").effect("count", "sum")
    eps = 1e-1
    tgt = "other" if nonlocal_ else "self"
    # the symmetric (|Δ|) kernel is identical in scatter and gather form
    F.emit(tgt, "avoidx", (Other("x") - Self("x")) / (abs_(Self("x") - Other("x")) + eps))
    F.emit(tgt, "avoidy", (Other("y") - Self("y")) / (abs_(Self("y") - Other("y")) + eps))
    F.emit(tgt, "count", 1.0)
    F.update("x", Self("x") + Self("vx"))
    F.update("y", Self("y") + Self("vy"))
    F.update("vx", Self("vx") * 0.9 + Eff("avoidx") / (Eff("count") + 1.0) * 0.02)
    F.update("vy", Self("vy") * 0.9 + Eff("avoidy") / (Eff("count") + 1.0) * 0.02)

    sim = Simulation.build(F, world_lo=(0.0, 0.0), world_hi=world)
    rs = np.random.RandomState(0)
    state = uniform_population(
        sim, n, capacity=int(n * 1.3), seed=3,
        extra={
            "vx": rs.uniform(-0.05, 0.05, n).astype(np.float32),
            "vy": rs.uniform(-0.05, 0.05, n).astype(np.float32),
        },
    )
    return sim, state, n

"""Subprocess helper: the dry-run machinery on a small mesh (8 fake
devices, reduced-but-structured configs) — lower+compile+analyze every
family and shape kind, including the multi-pod 'pod' axis."""

import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np  # noqa: E402


def main():
    import jax

    from repro.configs.base import SHAPES, ShapeSpec, get_arch, reduced
    from repro.launch.dryrun_lib import run_cell
    from repro.launch.mesh import make_test_mesh

    # structured-but-small configs: real enough to exercise every path
    def small(name):
        cfg = get_arch(name)
        return reduced(
            cfg, n_layers=4, d_model=128, n_heads=8, n_kv_heads=4, d_head=16,
            d_ff=256, vocab=512, scan_layers=True, remat=True,
            dtype="bfloat16",
        )

    # monkey-patch the registry view used by run_cell
    import repro.configs.base as base

    shapes = {
        "train_4k": ShapeSpec("train_4k", 256, 16, "train"),
        "prefill_32k": ShapeSpec("prefill_32k", 512, 8, "prefill"),
        "decode_32k": ShapeSpec("decode_32k", 512, 8, "decode"),
    }
    base.SHAPES.update(shapes)

    results = {}
    archs = ["granite-8b", "mixtral-8x22b", "zamba2-1.2b", "rwkv6-7b", "whisper-base"]
    meshes = {
        "single": make_test_mesh((2, 2), ("data", "model")),
        "multi": make_test_mesh((2, 2, 2), ("pod", "data", "model")),
    }
    for mesh_name, mesh in meshes.items():
        for arch in archs:
            cfg = small(arch)
            object.__setattr__(cfg, "name", arch)  # keep registry key
            base._REGISTRY[arch] = cfg
            for shape_name in shapes:
                r = run_cell(arch, shape_name, mesh, mesh_name, analysis=False)
                results[f"{arch}|{shape_name}|{mesh_name}"] = (
                    "ok" if r.ok else f"FAIL: {r.reason[:200]}"
                )
    n_fail = sum(1 for v in results.values() if v != "ok")
    print(json.dumps({"ok": n_fail == 0, "n": len(results),
                      "fails": {k: v for k, v in results.items() if v != "ok"}}))


if __name__ == "__main__":
    main()

"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.ops import (
    flash_attention,
    flash_attention_reference,
)
from repro.kernels.rwkv6.ops import wkv, wkv_reference
from repro.kernels.spatial_interact.ops import (
    spatial_interact,
    spatial_interact_reference,
)


# ---------------------------------------------------------------------------
# spatial_interact
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100), n=st.sampled_from([64, 192, 320]))
@settings(max_examples=8, deadline=None)
def test_spatial_interact_full_sweep(seed, n):
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.uniform(0, 15, n).astype(np.float32))
    y = jnp.asarray(rs.uniform(0, 5, n).astype(np.float32))
    hx = jnp.asarray(rs.randn(n).astype(np.float32))
    hy = jnp.asarray(rs.randn(n).astype(np.float32))
    alive = jnp.asarray(rs.rand(n) > 0.2)
    got = spatial_interact(x, y, hx, hy, alive, alpha=0.3, rho=1.0,
                           interpret=True, tq=64, tk=64)
    ref = spatial_interact_reference(x, y, hx, hy, alive, alpha=0.3, rho=1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_spatial_interact_banded_matches_full():
    rs = np.random.RandomState(7)
    n = 512
    x = jnp.asarray(rs.uniform(0, 40, n).astype(np.float32))
    y = jnp.asarray(rs.uniform(0, 5, n).astype(np.float32))
    hx = jnp.asarray(rs.randn(n).astype(np.float32))
    hy = jnp.asarray(rs.randn(n).astype(np.float32))
    alive = jnp.ones(n, bool)
    ref = spatial_interact_reference(x, y, hx, hy, alive, alpha=0.2, rho=1.0)
    # safe band: max #agents within a 2·rho x-interval
    xs = np.sort(np.asarray(x))
    band = int(max((xs < xv + 1.0).sum() - (xs < xv - 1.0).sum() for xv in xs)) + 8
    got = spatial_interact(x, y, hx, hy, alive, alpha=0.2, rho=1.0,
                           band=band, interpret=True, tq=64, tk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize(
    "b,s,h,kv,d,window",
    [
        (2, 256, 4, 2, 64, None),
        (2, 256, 4, 4, 64, 64),
        (1, 128, 2, 1, 32, None),
        (1, 512, 2, 2, 64, 128),
    ],
)
def test_flash_attention_sweep(b, s, h, kv, d, window, dtype, atol):
    rs = np.random.RandomState(0)
    q = jnp.asarray(rs.randn(b, s, h, d)).astype(dtype)
    k = jnp.asarray(rs.randn(b, s, kv, d)).astype(dtype)
    v = jnp.asarray(rs.randn(b, s, kv, d)).astype(dtype)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_matches_model_reference():
    """Kernel vs the model's jnp streaming implementation (same tiling idea)."""
    from repro.models import attention as A

    rs = np.random.RandomState(3)
    b, s, h, kv, d = 2, 256, 4, 2, 32
    q = jnp.asarray(rs.randn(b, s, h, d).astype(np.float32))
    k = jnp.asarray(rs.randn(b, s, kv, d).astype(np.float32))
    v = jnp.asarray(rs.randn(b, s, kv, d).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    ref = A.flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# rwkv6 wkv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("b,h,t,kd", [(2, 2, 128, 32), (1, 4, 64, 16)])
def test_wkv_sweep(b, h, t, kd, chunk):
    if t % chunk:
        pytest.skip("chunk must divide t")
    rs = np.random.RandomState(1)
    r = jnp.asarray(rs.randn(b, h, t, kd).astype(np.float32)) * 0.5
    k = jnp.asarray(rs.randn(b, h, t, kd).astype(np.float32)) * 0.5
    v = jnp.asarray(rs.randn(b, h, t, kd).astype(np.float32)) * 0.5
    logw = -jnp.exp(jnp.asarray(rs.randn(b, h, t, kd).astype(np.float32)) * 0.3)
    u = jnp.asarray(rs.randn(h, kd).astype(np.float32)) * 0.1
    got = wkv(r, k, v, logw, u, chunk=chunk, interpret=True)
    ref = wkv_reference(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_wkv_matches_model_chunked_form():
    """Kernel vs the model's chunked jnp implementation."""
    from repro.models import rwkv6 as R

    rs = np.random.RandomState(5)
    b, t, h, kd = 2, 128, 2, 16
    r = jnp.asarray(rs.randn(b, t, h, kd).astype(np.float32)) * 0.5
    k = jnp.asarray(rs.randn(b, t, h, kd).astype(np.float32)) * 0.5
    v = jnp.asarray(rs.randn(b, t, h, kd).astype(np.float32)) * 0.5
    logw = -jnp.exp(jnp.asarray(rs.randn(b, t, h, kd).astype(np.float32)) * 0.3)
    u = jnp.asarray(rs.randn(h, kd).astype(np.float32)) * 0.1
    s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    model_out, _ = R._wkv_chunked(r, k, v, logw, u, s0, chunk=32)
    kern_out = wkv(
        jnp.moveaxis(r, 2, 1), jnp.moveaxis(k, 2, 1),
        jnp.moveaxis(v, 2, 1), jnp.moveaxis(logw, 2, 1), u,
        chunk=32, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.moveaxis(kern_out, 1, 2)), np.asarray(model_out),
        rtol=1e-4, atol=1e-4,
    )

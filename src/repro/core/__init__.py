"""BRACE core: agents, combinators, spatial joins, the state-effect tick,
the single-node engine and the distributed shard_map runtime."""

from .agents import AgentState, EffectSpec, FieldSpec  # noqa: F401
from .engine import Engine, Simulation, uniform_population  # noqa: F401
from .join import Visibility  # noqa: F401
from .tick import TickPlan  # noqa: F401

"""The BRACE master (paper §3.3, Fig. 1).

Coordinates the cluster at *epoch* granularity: runs jitted epochs on the
workers (one shard_map call each), collects per-slab statistics, triggers
coordinated checkpoints, decides on repartitioning, and handles restart.

Fault-tolerance model (matching the paper + production practice):
  * coordinated checkpoint every ``checkpoint_every`` epochs (async write);
  * on failure, re-execute every epoch since the last checkpoint — the
    restore path is mesh-agnostic, so recovery may resume on a *different*
    device count (elastic shrink after a node loss, or grow);
  * stragglers: within an epoch the SPMD collectives are synchronous, so
    persistent skew — the dominant straggler source in spatial sims — is
    removed by the load balancer; transient node failure degenerates to the
    checkpoint/restart path.  (Speculative re-execution of individual map
    tasks does not apply: an epoch is one fused device program.)

Host-side ``epoch_hooks`` run on the gathered global population between
epochs (e.g. the predator simulation's spawn step).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from . import loadbalance
from .agents import AgentState
from .checkpoint import CheckpointManager
from .distribute import DistEngine
from .engine import Simulation


@dataclasses.dataclass
class MasterConfig:
    ticks_per_epoch: int = 32
    checkpoint_every: int = 4          # epochs; 0 = off
    checkpoint_dir: str | None = None
    load_balance: bool = True
    lb_imbalance_threshold: float = 1.25
    lb_pair_weight: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class EpochReport:
    epoch: int
    tick: int
    alive: np.ndarray          # [P] at epoch end
    imbalance: float
    rebalanced: bool
    stats: dict[str, np.ndarray]


class Master:
    def __init__(
        self,
        engine: DistEngine,
        config: MasterConfig,
        epoch_hooks: list[Callable[[AgentState, int], AgentState]] | None = None,
    ):
        self.engine = engine
        self.config = config
        self.epoch_hooks = list(epoch_hooks or [])
        self.ckpt = (
            CheckpointManager(config.checkpoint_dir)
            if config.checkpoint_dir
            else None
        )
        self.bounds = engine.uniform_bounds()
        self.tick = 0
        self.epoch = 0
        vis_x = engine.sim.plan.visibility.bounds[0]
        reach_x = engine.sim.plan.reach[0]
        # one-hop halo/migration soundness: slabs no narrower than the
        # visibility bound (and the per-tick reach).  Slabs wider than the
        # static local grid extent merely clamp into border cells (see
        # grid.py) — wide slabs are produced by the balancer only where the
        # population is sparse, so that is benign.
        self.min_width = max(vis_x, reach_x if np.isfinite(reach_x) else vis_x)

    # -- lifecycle -------------------------------------------------------------
    def start(self, global_state: AgentState) -> AgentState:
        """Place the initial population; returns the sharded state."""
        return self.engine.distribute(global_state, self.bounds)

    def restore_from_checkpoint(self, step: int | None = None) -> AgentState:
        """Elastic restore: works for any current device count."""
        assert self.ckpt is not None, "no checkpoint_dir configured"
        global_state, meta = self.ckpt.restore(step)
        self.tick = int(meta["tick"])
        self.epoch = int(meta["epoch"])
        saved_bounds = np.asarray(meta["bounds"])
        if len(saved_bounds) - 1 == self.engine.n_parts:
            self.bounds = saved_bounds
        else:  # different mesh size: restart from uniform slabs
            self.bounds = self.engine.uniform_bounds()
        return self.engine.distribute(global_state, self.bounds)

    # -- the master loop ---------------------------------------------------------
    def run_epoch(self, state: AgentState) -> tuple[AgentState, EpochReport]:
        cfg = self.config
        state, stats = self.engine.run_epoch(
            state,
            self.bounds,
            n_ticks=cfg.ticks_per_epoch,
            seed=cfg.seed,
            t0=self.tick,
        )
        self.tick += cfg.ticks_per_epoch
        self.epoch += 1

        for key in ("mig_overflow", "halo_overflow", "grid_overflow"):
            if key in stats and int(np.asarray(stats[key]).sum()) > 0:
                raise RuntimeError(
                    f"{key}={int(np.asarray(stats[key]).sum())}: capacity "
                    "under-provisioned — raise capacity_factor/halo_fraction"
                )

        alive = np.asarray(stats["alive"])[:, -1]  # [P]

        # ---- host-side hooks (e.g. spawning) --------------------------------
        if self.epoch_hooks:
            g = self.engine.gather(state)
            for hook in self.epoch_hooks:
                g = hook(g, self.tick)
            state = self.engine.distribute(g, self.bounds)
            alive = self._alive_per_slab(g)

        # ---- load balancing ---------------------------------------------------
        rebalanced = False
        decision = loadbalance.decide(
            self.bounds,
            alive,
            self.min_width,
            pair_weight=cfg.lb_pair_weight,
            imbalance_threshold=cfg.lb_imbalance_threshold,
        )
        if cfg.load_balance and decision.rebalance:
            g = self.engine.gather(state)
            self.bounds = decision.new_bounds
            state = self.engine.distribute(g, self.bounds)
            rebalanced = True

        # ---- coordinated checkpoint -------------------------------------------
        if self.ckpt and cfg.checkpoint_every and self.epoch % cfg.checkpoint_every == 0:
            g = self.engine.gather(state)
            self.ckpt.save(
                self.tick,
                g,
                meta={
                    "tick": self.tick,
                    "epoch": self.epoch,
                    "bounds": [float(b) for b in self.bounds],
                    "seed": cfg.seed,
                    "n_parts": self.engine.n_parts,
                },
            )

        report = EpochReport(
            epoch=self.epoch,
            tick=self.tick,
            alive=alive,
            imbalance=decision.imbalance,
            rebalanced=rebalanced,
            stats=stats,
        )
        return state, report

    def run(self, state: AgentState, n_epochs: int) -> tuple[AgentState, list[EpochReport]]:
        reports = []
        for _ in range(n_epochs):
            state, rep = self.run_epoch(state)
            reports.append(rep)
        if self.ckpt:
            self.ckpt.wait()
        return state, reports

    # -- helpers ---------------------------------------------------------------
    def _alive_per_slab(self, g: AgentState) -> np.ndarray:
        xf = self.engine.sim.plan.visibility.pos_fields[0]
        x = np.asarray(g.fields[xf])
        alive = np.asarray(g.alive)
        out = np.zeros(self.engine.n_parts)
        for p in range(self.engine.n_parts):
            lo = -np.inf if p == 0 else self.bounds[p]
            hi = np.inf if p == self.engine.n_parts - 1 else self.bounds[p + 1]
            out[p] = np.sum(alive & (x >= lo) & (x < hi))
        return out

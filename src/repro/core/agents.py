"""Agent containers: fixed-capacity struct-of-arrays with alive masks.

The paper stores agents as C++ objects; a TPU-native runtime needs static
shapes and vectorized access, so a population is a struct-of-arrays
``AgentState`` with a boolean ``alive`` mask (dead/free slots are reusable —
see the predator simulation's spawn logic).  Effects are *transient*: they
are created at the start of the query phase (reset to the combinator
identity θ, paper App. A) and consumed by the update phase, so they are not
part of the persistent state.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """A state field: public attribute updated only at tick boundaries."""

    name: str
    shape: tuple = ()
    dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class EffectSpec:
    """An effect field with its ⊕ combinator (and payloads for *_BY)."""

    name: str
    comb: str = "sum"  # key into combinators.REGISTRY
    shape: tuple = ()
    dtype: Any = jnp.float32
    payload: tuple = ()  # tuple[(name, shape, dtype)] for min_by/max_by


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AgentState:
    """Struct-of-arrays agent population (capacity = alive.shape[0])."""

    alive: Array  # bool[N]
    oid: Array    # int32[N] stable agent id
    fields: dict[str, Array]  # each [N, *field_shape]

    @property
    def capacity(self) -> int:
        return self.alive.shape[0]

    def num_alive(self) -> Array:
        return jnp.sum(self.alive.astype(jnp.int32))

    def replace_fields(self, **updates: Array) -> "AgentState":
        new = dict(self.fields)
        new.update(updates)
        return AgentState(alive=self.alive, oid=self.oid, fields=new)


def init_state(field_specs: list[FieldSpec], capacity: int) -> AgentState:
    """All-dead population of the given capacity."""
    fields = {
        f.name: jnp.zeros((capacity,) + tuple(f.shape), f.dtype) for f in field_specs
    }
    return AgentState(
        alive=jnp.zeros((capacity,), bool),
        oid=jnp.zeros((capacity,), jnp.int32),
        fields=fields,
    )


def from_numpy(field_specs: list[FieldSpec], capacity: int, oid, **arrays) -> AgentState:
    """Build a state from per-agent numpy/jnp arrays (n <= capacity)."""
    n = len(oid)
    if n > capacity:
        raise ValueError(f"{n} agents exceed capacity {capacity}")
    state = init_state(field_specs, capacity)
    alive = state.alive.at[:n].set(True)
    oid_arr = state.oid.at[:n].set(jnp.asarray(oid, jnp.int32))
    fields = {}
    for f in field_specs:
        tgt = state.fields[f.name]
        if f.name in arrays:
            src = jnp.asarray(arrays[f.name], f.dtype)
            tgt = tgt.at[:n].set(src)
        fields[f.name] = tgt
    return AgentState(alive=alive, oid=oid_arr, fields=fields)


def take(state: AgentState, idx: Array) -> AgentState:
    """Gather agents by slot index (out-of-range rows must be masked by caller)."""
    return AgentState(
        alive=state.alive[idx],
        oid=state.oid[idx],
        fields={k: v[idx] for k, v in state.fields.items()},
    )


def concatenate(states: list[AgentState]) -> AgentState:
    return AgentState(
        alive=jnp.concatenate([s.alive for s in states]),
        oid=jnp.concatenate([s.oid for s in states]),
        fields={
            k: jnp.concatenate([s.fields[k] for s in states])
            for k in states[0].fields
        },
    )


def compact(state: AgentState) -> AgentState:
    """Pack alive agents to the front (stable order by slot)."""
    order = jnp.argsort(~state.alive, stable=True)
    return take(state, order)

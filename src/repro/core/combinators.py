"""Effect combinators (the paper's ⊕ operators).

The state-effect pattern requires every effect field to carry a
*decomposable, order-independent* combinator so that concurrent effect
assignments during the query phase commute (paper §2.1).  Each combinator
provides:

  * ``identity``   — the θ vector used to reset effects at tick boundaries,
  * ``combine``    — the binary ⊕ (associative + commutative), used by
                     reduce₂ when partial aggregates from remote partitions
                     are merged (paper Fig. 10),
  * ``reduce``     — a masked reduction over a candidate axis (the vectorized
                     foreach-loop in the query phase),
  * ``scatter``    — ⊕-scatter of contributions into a target agent's effect
                     slot (non-local effect assignment, paper §3.2).

Values are either plain arrays or — for the ``*_BY`` argmin/argmax style
combinators needed by e.g. the traffic simulation ("nearest lead vehicle") —
dicts ``{"key": arr, <payload>: arr, ...}``.  ``MIN_BY``/``MAX_BY`` are
decomposable and order-independent (ties broken deterministically by key
then payload order), so they are legal effect combinators under the paper's
definition.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def _masked(x: Array, mask: Array, fill) -> Array:
    mask = jnp.reshape(mask, mask.shape + (1,) * (x.ndim - mask.ndim))
    return jnp.where(mask, x, jnp.asarray(fill, dtype=x.dtype))


@dataclasses.dataclass(frozen=True)
class Combinator:
    """A decomposable, order-independent effect aggregation operator."""

    name: str
    # identity element for a plain array of (shape, dtype)
    _identity: Callable[[tuple, Any], Array]
    _combine: Callable[[Array, Array], Array]
    _reduce: Callable[[Array, Array, int], Array]  # (contrib, mask, axis)
    _scatter: Callable[[Array, Array, Array, Array], Array] | None  # (tgt, idx, contrib, mask)

    # ---- plain-array protocol -------------------------------------------------
    def identity(self, shape: tuple, dtype: Any) -> Array:
        return self._identity(shape, dtype)

    def combine(self, a: Array, b: Array) -> Array:
        return self._combine(a, b)

    def reduce(self, contrib: Array, mask: Array, axis: int = 1) -> Array:
        return self._reduce(contrib, mask, axis)

    def scatter(self, target: Array, idx: Array, contrib: Array, mask: Array) -> Array:
        if self._scatter is None:
            raise NotImplementedError(
                f"combinator {self.name!r} does not support non-local (scatter) "
                "effect assignment; use effect inversion to make it local"
            )
        return self._scatter(target, idx, contrib, mask)


# ---------------------------------------------------------------------------
# SUM / MIN / MAX / OR / AND
# ---------------------------------------------------------------------------

def _scatter_via(op_name: str):
    def scatter(target, idx, contrib, mask, *, fill):
        # Drop masked-out contributions into a dump row one past the end.
        n = target.shape[0]
        safe_idx = jnp.where(mask, idx, n)
        padded = jnp.concatenate(
            [target, target[:1]], axis=0
        )  # dump row (value irrelevant)
        contrib = _masked(contrib, mask, fill)
        flat_idx = safe_idx.reshape(-1)
        flat_contrib = contrib.reshape((-1,) + contrib.shape[idx.ndim:])
        updated = getattr(padded.at[flat_idx], op_name)(flat_contrib)
        return updated[:n]

    return scatter


SUM = Combinator(
    "sum",
    _identity=lambda shape, dtype: jnp.zeros(shape, dtype),
    _combine=lambda a, b: a + b,
    _reduce=lambda c, m, ax: jnp.sum(_masked(c, m, 0), axis=ax),
    _scatter=lambda t, i, c, m: _scatter_via("add")(t, i, c, m, fill=0),
)

_BIG = 3.0e38  # below f32 max; used as +/- inf that survives arithmetic

MIN = Combinator(
    "min",
    _identity=lambda shape, dtype: jnp.full(shape, _BIG, dtype),
    _combine=lambda a, b: jnp.minimum(a, b),
    _reduce=lambda c, m, ax: jnp.min(_masked(c, m, _BIG), axis=ax),
    _scatter=lambda t, i, c, m: _scatter_via("min")(t, i, c, m, fill=_BIG),
)

MAX = Combinator(
    "max",
    _identity=lambda shape, dtype: jnp.full(shape, -_BIG, dtype),
    _combine=lambda a, b: jnp.maximum(a, b),
    _reduce=lambda c, m, ax: jnp.max(_masked(c, m, -_BIG), axis=ax),
    _scatter=lambda t, i, c, m: _scatter_via("max")(t, i, c, m, fill=-_BIG),
)

OR = Combinator(
    "or",
    _identity=lambda shape, dtype: jnp.zeros(shape, dtype=bool),
    _combine=lambda a, b: jnp.logical_or(a, b),
    _reduce=lambda c, m, ax: jnp.any(jnp.logical_and(c, m), axis=ax),
    _scatter=lambda t, i, c, m: _scatter_via("max")(t, i, c.astype(t.dtype), m, fill=0),
)


# ---------------------------------------------------------------------------
# MIN_BY / MAX_BY — argopt combinators over {"key": ..., payload...} dicts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArgOptCombinator:
    """Selects the whole record whose key is smallest (MIN_BY) / largest (MAX_BY).

    Decomposable and order-independent: ⊕ keeps the record with the better
    key (ties keep either — with distinct float keys in the sims this is a
    measure-zero event; determinism is preserved within a fixed reduction
    order, and across orders only up to key ties).
    """

    name: str
    sign: float  # +1 for MIN_BY, -1 for MAX_BY

    def identity(self, payload_specs: dict[str, tuple[tuple, Any]]) -> dict[str, Array]:
        out = {"key": jnp.full((), self.sign * _BIG, jnp.float32)}
        for pname, (shape, dtype) in payload_specs.items():
            out[pname] = jnp.zeros(shape, dtype)
        return out

    def combine(self, a: dict[str, Array], b: dict[str, Array]) -> dict[str, Array]:
        take_a = (self.sign * a["key"]) <= (self.sign * b["key"])
        return {
            k: jnp.where(jnp.reshape(take_a, take_a.shape + (1,) * (a[k].ndim - take_a.ndim)), a[k], b[k])
            for k in a
        }

    def reduce(self, contrib: dict[str, Array], mask: Array, axis: int = 1) -> dict[str, Array]:
        key = _masked(contrib["key"] * self.sign, mask, _BIG)
        sel = jnp.argmin(key, axis=axis)  # [N]
        out = {}
        for k, v in contrib.items():
            idx = jnp.expand_dims(sel, axis)  # [N, 1]
            idx = jnp.reshape(idx, idx.shape + (1,) * (v.ndim - idx.ndim))
            taken = jnp.take_along_axis(v, idx, axis=axis)
            out[k] = jnp.squeeze(taken, axis=axis)
        # if nothing was selected (all masked), fall back to the identity key
        none = ~jnp.any(mask, axis=axis)
        out["key"] = jnp.where(none, self.sign * _BIG, out["key"])
        return out

    def scatter(self, *a, **k):  # pragma: no cover - guarded by compiler
        raise NotImplementedError(
            f"{self.name} does not support non-local assignment; invert the effect"
        )


MIN_BY = ArgOptCombinator("min_by", +1.0)
MAX_BY = ArgOptCombinator("max_by", -1.0)

REGISTRY: dict[str, Any] = {
    "sum": SUM,
    "min": MIN,
    "max": MAX,
    "or": OR,
    "min_by": MIN_BY,
    "max_by": MAX_BY,
}


def get(name: str):
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown combinator {name!r}; available: {sorted(REGISTRY)}")

"""Uniform-grid spatial index — the TPU-native replacement for the KD-tree.

The paper's prototype uses a per-node KD-tree (§5.1) to turn the query
phase's neighbor enumeration into an orthogonal range query.  Pointer-based
tree descent is data-dependent control flow, which TPUs execute poorly, so
we use the classic cell-list structure instead: sort agents by cell id and
materialize a dense ``[n_cells, capacity]`` table of slot indices.  A range
query for visibility box ρ then becomes a gather over the 3×3 stencil of
neighboring cells — fully vectorized, static shapes, same asymptotic win as
the KD-tree (benchmarks/fig3, fig4).

Design notes:
  * cell sizes ≥ visibility bound per axis ⇒ the stencil covers every
    agent's visible region;
  * the grid *origin* is a dynamic argument (the distributed runtime slides
    a local grid over its slab, whose bounds change under load balancing);
  * out-of-extent agents clamp into border cells.  Clamping only moves
    agents inward, so any pair within the visibility bound stays within
    stencil adjacency — correctness is preserved, only border-cell density
    (and hence the static ``capacity``) is affected.  Capacity overflow is
    the one lossy event; it is counted and surfaced in engine stats;
  * periodic axes (traffic's circular road) wrap the stencil.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static grid geometry (origin is supplied dynamically)."""

    nx: int
    ny: int
    sx: float  # cell extent per axis
    sy: float
    capacity: int  # max agents materialized per cell
    periodic_x: bool = False
    periodic_y: bool = False

    @property
    def n_cells(self) -> int:
        return self.nx * self.ny


def make_grid(
    extent: tuple[float, float],
    visibility: tuple[float, float],
    n_agents: int,
    capacity_factor: float = 3.0,
    max_cells: int = 16384,
    periodic: tuple[bool, bool] = (False, False),
    cell_capacity: int | None = None,
) -> GridSpec:
    """Choose a grid: cells no smaller than the visibility box per axis.

    ``cell_capacity`` overrides the automatic Poisson-ish sizing — needed
    for simulations whose agents cluster far beyond uniform density (e.g. a
    fish school collapsing into tight groups).  Overflow is always counted
    at runtime, so under-provisioning is detected, never silent.
    """
    nx = max(1, int(extent[0] / max(visibility[0], 1e-9)))
    ny = max(1, int(extent[1] / max(visibility[1], 1e-9)))
    while nx * ny > max_cells:  # keep the table bounded
        if nx >= ny:
            nx = max(1, nx // 2)
        else:
            ny = max(1, ny // 2)
    sx = extent[0] / nx
    sy = extent[1] / ny
    if cell_capacity is not None:
        capacity = int(cell_capacity)
    else:
        mean = max(1.0, n_agents / (nx * ny))
        # mean + Poisson tail + slack, scaled by the caller's factor
        capacity = int(math.ceil((mean + 3.0 * math.sqrt(mean) + 4.0) * capacity_factor / 3.0))
        capacity = max(16, capacity)
        capacity = min(capacity, max(16, n_agents))
    return GridSpec(
        nx=nx, ny=ny, sx=sx, sy=sy, capacity=capacity,
        periodic_x=periodic[0], periodic_y=periodic[1],
    )


def _coords(gs: GridSpec, lo, x: Array, y: Array) -> tuple[Array, Array]:
    cx = jnp.clip(jnp.floor((x - lo[0]) / gs.sx).astype(jnp.int32), 0, gs.nx - 1)
    cy = jnp.clip(jnp.floor((y - lo[1]) / gs.sy).astype(jnp.int32), 0, gs.ny - 1)
    return cx, cy


def cell_id(gs: GridSpec, lo, x: Array, y: Array) -> Array:
    cx, cy = _coords(gs, lo, x, y)
    return cx * gs.ny + cy


def build_table(gs: GridSpec, lo, x: Array, y: Array, alive: Array):
    """Dense cell→slots table.

    Returns ``(table [n_cells, capacity] int32, overflow int32)``; empty
    entries are ``n`` (one past the last slot, caller masks).
    """
    n = x.shape[0]
    cid = jnp.where(alive, cell_id(gs, lo, x, y), gs.n_cells)  # dead → ghost cell
    order = jnp.argsort(cid, stable=True)
    cid_sorted = cid[order]
    # rank of each agent within its cell: position minus position of run start
    pos = jnp.arange(n)
    run_first = jnp.concatenate(
        [jnp.ones((1,), bool), cid_sorted[1:] != cid_sorted[:-1]]
    )
    run_id = jnp.cumsum(run_first.astype(jnp.int32)) - 1  # 0-based run index
    run_start = jax.ops.segment_min(pos, run_id, num_segments=n)
    rank = pos - run_start[run_id]

    valid = cid_sorted < gs.n_cells
    in_cap = rank < gs.capacity
    keep = valid & in_cap
    overflow = jnp.sum((valid & ~in_cap).astype(jnp.int32))

    table = jnp.full((gs.n_cells + 1, gs.capacity), n, jnp.int32)
    safe_cid = jnp.where(keep, cid_sorted, gs.n_cells)
    safe_rank = jnp.where(keep, rank, 0).astype(jnp.int32)
    table = table.at[safe_cid, safe_rank].set(
        jnp.where(keep, order.astype(jnp.int32), n)
    )
    return table[: gs.n_cells], overflow


_STENCIL = np.array(
    [(dx, dy) for dx in (-1, 0, 1) for dy in (-1, 0, 1)], dtype=np.int32
)


def candidates(gs: GridSpec, lo, table: Array, x: Array, y: Array):
    """Per-agent candidate slot indices from the 3×3 stencil.

    Returns ``(idx [N, 9*capacity], valid [N, 9*capacity])``; ``idx`` holds
    ``n`` where invalid.
    """
    n = x.shape[0]
    cx, cy = _coords(gs, lo, x, y)

    st = jnp.asarray(_STENCIL)  # [9, 2]
    ncx = cx[:, None] + st[None, :, 0]  # [N, 9]
    ncy = cy[:, None] + st[None, :, 1]
    if gs.periodic_x:
        ncx = jnp.mod(ncx, gs.nx)
        okx = jnp.ones_like(ncx, dtype=bool)
    else:
        okx = (ncx >= 0) & (ncx < gs.nx)
        ncx = jnp.clip(ncx, 0, gs.nx - 1)
    if gs.periodic_y:
        ncy = jnp.mod(ncy, gs.ny)
        oky = jnp.ones_like(ncy, dtype=bool)
    else:
        oky = (ncy >= 0) & (ncy < gs.ny)
        ncy = jnp.clip(ncy, 0, gs.ny - 1)
    in_grid = okx & oky
    ncell = ncx * gs.ny + ncy

    cand = table[ncell]  # [N, 9, capacity]
    cand = jnp.where(in_grid[:, :, None], cand, n)
    cand = cand.reshape(n, -1)
    valid = cand < n
    return cand, valid


def brute_candidates(n: int) -> tuple[Array, Array]:
    """No-index fallback: every agent is a candidate of every agent (Fig. 3's
    quadratic baseline)."""
    idx = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (n, n))
    valid = jnp.ones((n, n), bool)
    return idx, valid

"""Coordinated epoch-boundary checkpointing (paper §3.3, Fault Tolerance).

The paper proposes *coordinated checkpoints* at master-determined tick
boundaries, with failure recovery by re-executing all ticks since the last
checkpoint — but leaves the implementation as future work (§5.1).  We
implement it:

  * checkpoints are taken at epoch boundaries only (amortization argument);
  * the snapshot is the *global* population (gathered from the mesh), plus
    the master state (tick counter, slab bounds, RNG seed) in a JSON
    manifest — deliberately **mesh-agnostic**, so a checkpoint written on P
    devices restores onto P′ ≠ P devices (elastic scaling / shrink-on-
    failure);
  * writes are asynchronous: the device→host gather happens synchronously
    (cheap, main-memory sized), the file write happens on a background
    thread so the next epoch overlaps with I/O;
  * ``latest``/atomic-rename protocol makes a torn write unrecoverable at
    most once — recovery falls back to the previous checkpoint.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any

import jax
import numpy as np

from .agents import AgentState


def _to_numpy_tree(state: AgentState) -> dict[str, np.ndarray]:
    flat = {"alive": np.asarray(state.alive), "oid": np.asarray(state.oid)}
    for k, v in state.fields.items():
        flat[f"field.{k}"] = np.asarray(v)
    return flat


def _from_numpy_tree(flat: dict[str, np.ndarray]) -> AgentState:
    import jax.numpy as jnp

    fields = {
        k[len("field."):]: jnp.asarray(v)
        for k, v in flat.items()
        if k.startswith("field.")
    }
    return AgentState(
        alive=jnp.asarray(flat["alive"]),
        oid=jnp.asarray(flat["oid"]),
        fields=fields,
    )


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, state: AgentState, meta: dict[str, Any]) -> str:
        """Snapshot now; write (a)synchronously.  Returns the target path."""
        self.wait()  # never overlap two writes
        flat = _to_numpy_tree(state)  # host copy taken synchronously
        path = os.path.join(self.directory, f"ckpt_{step:010d}")
        meta = dict(meta, step=step, time=time.time())

        def _write():
            tmp = path + ".tmp.npz"
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, path + ".npz")
            mtmp = path + ".meta.tmp"
            with open(mtmp, "w") as f:
                json.dump(meta, f)
            os.replace(mtmp, path + ".meta.json")
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()
        return path

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep] if self.keep else []:
            for suffix in (".npz", ".meta.json"):
                try:
                    os.remove(os.path.join(self.directory, f"ckpt_{s:010d}{suffix}"))
                except FileNotFoundError:
                    pass

    # -- read ----------------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and name.endswith(".meta.json"):
                out.append(int(name[len("ckpt_"):-len(".meta.json")]))
        return sorted(out)

    def restore(self, step: int | None = None) -> tuple[AgentState, dict[str, Any]]:
        """Load the latest (or a specific) checkpoint; skips torn writes."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        candidates = [step] if step is not None else list(reversed(steps))
        last_err: Exception | None = None
        for s in candidates:
            base = os.path.join(self.directory, f"ckpt_{s:010d}")
            try:
                with open(base + ".meta.json") as f:
                    meta = json.load(f)
                with np.load(base + ".npz") as z:
                    flat = {k: z[k] for k in z.files}
                return _from_numpy_tree(flat), meta
            except Exception as e:  # torn write → try the previous one
                last_err = e
        raise RuntimeError(f"all checkpoints unreadable: {last_err}")

"""BRACE distributed runtime: map-reduce-reduce over a shard_map mesh.

The paper's dataflow (§3.2, Fig. 9/10) maps onto TPU collectives as:

  map₁   (update + distribute + replicate)  →  migration ppermute (bounded by
         reachability) + **halo exchange** ppermute (bounded by visibility)
  reduce₁ (query phase over owned ∪ replicas) →  local spatial join; only the
         ownership-masked rows execute their query
  map₂   (identity, "can be eliminated")     →  eliminated, exactly as §3.2
  reduce₂ (⊕-combine non-local partials)     →  reverse ppermute of halo
         partial-effect buffers, ⊕-scatter at the owner

The whole epoch (``ticks_per_epoch`` iterations) runs inside one jitted
``shard_map`` call — the paper's "master only interacts with workers every
epoch" taken to its in-memory extreme: zero host round-trips within an
epoch.  Collocation (§3.3) is implicit: an agent that stays in its slab
never leaves device HBM; only halo replicas and migrants touch the ICI.

Partitioning is 1-D over the x axis (slabs), matching the paper's 1-D load
balancer.  Slab boundaries are a *dynamic* input, so the master can
rebalance between epochs without recompiling.

Requirements checked at build time: P ≥ 2, slab width ≥ visibility (halo =
one neighbor hop) and ≥ reach (migration = one neighbor hop).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import combinators as combs
from . import grid as gridlib
from .agents import AgentState, concatenate, take
from .engine import Simulation
from .join import run_query
from .tick import TickPlan, update_phase

Array = jax.Array
AXIS = "space"


# ---------------------------------------------------------------------------
# buffer packing
# ---------------------------------------------------------------------------

def pack(state: AgentState, mask: Array, size: int):
    """Select up to ``size`` masked agents into a fixed-size buffer.

    Returns (buffer AgentState [size], src_idx [size], overflow count).
    Buffer rows beyond the masked population are dead (alive=False).
    """
    k = state.capacity
    prio = jnp.where(mask, jnp.arange(k, dtype=jnp.int32), k)
    order = jnp.argsort(prio)[:size]
    buf = take(state, order)
    valid = mask[order]
    buf = AgentState(alive=buf.alive & valid, oid=buf.oid, fields=buf.fields)
    overflow = jnp.maximum(0, jnp.sum(mask.astype(jnp.int32)) - size)
    return buf, order.astype(jnp.int32), overflow


def merge_into_free_slots(state: AgentState, incoming: AgentState):
    """Place incoming (alive) agents into this shard's free slots."""
    k, m = state.capacity, incoming.capacity
    free_order = jnp.argsort(state.alive)[:m]  # False sorts first
    n_free = jnp.sum((~state.alive).astype(jnp.int32))
    placeable = incoming.alive & (jnp.arange(m) < n_free)
    overflow = jnp.sum(incoming.alive.astype(jnp.int32)) - jnp.sum(
        placeable.astype(jnp.int32)
    )

    def put(dst, src):
        cur = dst[free_order]
        sel = jnp.reshape(placeable, placeable.shape + (1,) * (src.ndim - 1))
        return dst.at[free_order].set(jnp.where(sel, src, cur))

    fields = {kf: put(state.fields[kf], incoming.fields[kf]) for kf in state.fields}
    alive = state.alive.at[free_order].set(
        jnp.where(placeable, True, state.alive[free_order])
    )
    oid = put(state.oid, incoming.oid)
    return AgentState(alive=alive, oid=oid, fields=fields), overflow


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistConfig:
    n_parts: int
    capacity: int        # owned slots per device
    halo_capacity: int   # halo buffer slots per side
    mig_capacity: int    # migration buffer slots per side
    periodic: bool
    world_lo: tuple[float, float]
    world_hi: tuple[float, float]
    grid: gridlib.GridSpec | None  # local per-slab grid (None = no index)
    two_pass: bool       # map-reduce-reduce (non-local effects present)

    @property
    def local_rows(self) -> int:
        return self.capacity + 2 * self.halo_capacity


def plan_config(
    sim: Simulation,
    n_parts: int,
    n_agents_hint: int,
    index: str = "grid",
    capacity_factor: float = 3.0,
    halo_fraction: float = 0.5,
    two_pass: bool | None = None,
    cell_capacity: int | None = None,
) -> DistConfig:
    plan = sim.plan
    world_lo, world_hi = sim.world_lo, sim.world_hi
    extent_x = world_hi[0] - world_lo[0]
    vis_x = plan.visibility.bounds[0]
    periodic = plan.visibility.periods[0] is not None
    if n_parts < 2:
        raise ValueError("distributed runtime needs ≥ 2 partitions; use Engine")
    min_slab = extent_x / n_parts  # load balancer enforces ≥ this / slack
    if periodic and min_slab < 2 * vis_x:
        raise ValueError(
            f"slab width {min_slab:.3g} < 2×visibility {2 * vis_x:.3g}: "
            "halo replicas would alias around the ring"
        )

    capacity = max(16, int(math.ceil(n_agents_hint / n_parts * capacity_factor)))
    halo_capacity = max(16, int(capacity * halo_fraction))
    mig_capacity = max(16, int(capacity * halo_fraction / 2))

    grid = None
    if index == "grid":
        # local grid covers the widest slab the balancer may produce (4× the
        # mean width) plus one visibility margin per side; out-of-extent
        # agents clamp into border cells (correct, just denser — grid.py).
        slab_extent = extent_x / n_parts * 4.0 + 2 * vis_x
        grid = gridlib.make_grid(
            (slab_extent, world_hi[1] - world_lo[1]),
            plan.visibility.bounds,
            n_agents_hint // n_parts * 4,
            capacity_factor=capacity_factor * 2,  # grid slots are cheap ints
            periodic=(False, False),  # wrap handled by the halo ring
            cell_capacity=cell_capacity,
        )
    if two_pass is None:
        two_pass = plan.has_nonlocal
    return DistConfig(
        n_parts=n_parts,
        capacity=capacity,
        halo_capacity=halo_capacity,
        mig_capacity=mig_capacity,
        periodic=periodic,
        world_lo=world_lo,
        world_hi=world_hi,
        grid=grid,
        two_pass=two_pass,
    )


# ---------------------------------------------------------------------------
# the per-epoch shard_map body
# ---------------------------------------------------------------------------

def _perms(p: int, periodic: bool):
    left = [(i, i - 1) for i in range(1, p)]
    right = [(i, i + 1) for i in range(p - 1)]
    if periodic:
        left.append((0, p - 1))
        right.append((p - 1, 0))
    return left, right


def _ppermute(tree, perm):
    return jax.tree.map(lambda a: jax.lax.ppermute(a, AXIS, perm), tree)


def make_epoch_fn(plan: TickPlan, params: dict, cfg: DistConfig):
    """Build the shard_map body: (shard, bounds, rng, t0, n_ticks) → shard."""
    xf, yf = plan.visibility.pos_fields
    vis_x = plan.visibility.bounds[0]
    period = plan.visibility.periods[0]
    perm_left, perm_right = _perms(cfg.n_parts, cfg.periodic)
    extent_x = cfg.world_hi[0] - cfg.world_lo[0]
    scatterable = [
        es for es in plan.effect_specs
        if not isinstance(combs.get(es.comb), combs.ArgOptCombinator)
    ]

    def one_tick(state: AgentState, bounds: Array, rng: Array, t: Array):
        me = jax.lax.axis_index(AXIS)
        lo = bounds[me]
        hi = bounds[me + 1]
        x = state.fields[xf]
        stats = {}

        # ---- map₁ part 1: migration (distributeᵗ for agents that moved) ----
        belongs = (x >= lo) & (x < hi)
        center = (lo + hi) * 0.5
        d = x - center
        if cfg.periodic:
            d = d - extent_x * jnp.round(d / extent_x)
        go_left = state.alive & ~belongs & (d < 0)
        go_right = state.alive & ~belongs & (d >= 0)
        if not cfg.periodic:
            # edge slabs extend to ±∞: agents past the world box stay put
            go_left = go_left & (me > 0)
            go_right = go_right & (me < cfg.n_parts - 1)
        buf_l, _, ovl = pack(state, go_left, cfg.mig_capacity)
        buf_r, _, ovr = pack(state, go_right, cfg.mig_capacity)
        # remove emigrants, then exchange
        state = AgentState(
            alive=state.alive & ~(go_left | go_right),
            oid=state.oid,
            fields=state.fields,
        )
        inc_from_right = _ppermute(buf_l, perm_left)   # right nbr's leftbound
        inc_from_left = _ppermute(buf_r, perm_right)   # left nbr's rightbound
        state, ovm1 = merge_into_free_slots(state, inc_from_left)
        state, ovm2 = merge_into_free_slots(state, inc_from_right)
        stats["mig_overflow"] = ovl + ovr + ovm1 + ovm2
        stats["migrated"] = jnp.sum((go_left | go_right).astype(jnp.int32))

        # ---- map₁ part 2: replication (halo exchange) -----------------------
        x = state.fields[xf]
        near_left = state.alive & (x < lo + vis_x)
        near_right = state.alive & (x >= hi - vis_x)
        send_l, src_l, ohl = pack(state, near_left, cfg.halo_capacity)
        send_r, src_r, ohr = pack(state, near_right, cfg.halo_capacity)
        halo_from_right = _ppermute(send_l, perm_left)
        halo_from_left = _ppermute(send_r, perm_right)
        stats["halo_overflow"] = ohl + ohr
        stats["halo"] = jnp.sum(
            halo_from_left.alive.astype(jnp.int32)
        ) + jnp.sum(halo_from_right.alive.astype(jnp.int32))

        if cfg.periodic:
            # unwrap coordinates across the seam so the local grid is
            # contiguous (visibility masks already wrap)
            last = cfg.n_parts - 1
            adj_l = jnp.where(me == 0, -extent_x, 0.0)
            adj_r = jnp.where(me == last, extent_x, 0.0)
            halo_from_left = halo_from_left.replace_fields(
                **{xf: halo_from_left.fields[xf] + adj_l}
            )
            halo_from_right = halo_from_right.replace_fields(
                **{xf: halo_from_right.fields[xf] + adj_r}
            )

        local = concatenate([state, halo_from_left, halo_from_right])
        k, h = cfg.capacity, cfg.halo_capacity
        owned_mask = jnp.arange(local.capacity) < k

        # ---- reduce₁: query phase over owned ∪ replicas ---------------------
        lx = local.fields[xf]
        ly = local.fields[yf]
        if cfg.grid is None:
            cand, valid = gridlib.brute_candidates(local.capacity)
        else:
            glo = (lo - vis_x, cfg.world_lo[1])
            table, gov = gridlib.build_table(cfg.grid, glo, lx, ly, local.alive)
            cand, valid = gridlib.candidates(cfg.grid, glo, table, lx, ly)
            stats["grid_overflow"] = gov
        effects = run_query(
            local, cand, valid, plan.pair_fn, plan.effect_specs,
            plan.visibility, params, self_mask=owned_mask,
        )

        # ---- reduce₂: return non-local partials to their owners -------------
        if cfg.two_pass:
            part_from_left = {es.name: jax.tree.map(lambda a: a[k:k + h], effects[es.name])
                              for es in scatterable}
            part_from_right = {es.name: jax.tree.map(lambda a: a[k + h:k + 2 * h], effects[es.name])
                               for es in scatterable}
            # partials for halo_from_left go back to the left owner, etc.
            ret_from_right = _ppermute(part_from_left, perm_left)
            ret_from_left = _ppermute(part_from_right, perm_right)
            for es in scatterable:
                comb = combs.get(es.comb)
                eff = effects[es.name]
                # I sent send_r (src_r) to the right; its partials come back
                # from the right neighbor, and vice versa.
                eff = comb.scatter(
                    eff, src_r, ret_from_right[es.name], send_r.alive
                )
                eff = comb.scatter(
                    eff, src_l, ret_from_left[es.name], send_l.alive
                )
                effects[es.name] = eff

        owned_effects = {
            name: jax.tree.map(lambda a: a[:k], val) for name, val in effects.items()
        }

        # ---- map₁ of t+1 part 0: update phase -------------------------------
        state = update_phase(plan, state, owned_effects, params, rng, t)
        stats["alive"] = state.num_alive()
        return state, stats

    def epoch_fn(state: AgentState, bounds: Array, rng: Array, t0: Array, ticks: Array):
        def body(carry, i):
            st = carry
            key = jax.random.fold_in(rng, t0 + i)
            st, stats = one_tick(st, bounds, key, t0 + i)
            return st, stats

        state, stats = jax.lax.scan(body, state, ticks)
        # leading axis of size 1 per shard → [P, T] outside shard_map
        stats = {kk: v[None] for kk, v in stats.items()}
        return state, stats

    return epoch_fn


# ---------------------------------------------------------------------------
# host-side driver
# ---------------------------------------------------------------------------

class DistEngine:
    """Distributed BRACE runtime over a 1-D device mesh.

    ``run_epoch`` is the only device round-trip; partitioning, checkpointing
    and load balancing happen between epochs (see core/master.py).
    """

    def __init__(
        self,
        sim: Simulation,
        n_agents_hint: int,
        mesh: jax.sharding.Mesh | None = None,
        index: str = "grid",
        capacity_factor: float = 3.0,
        halo_fraction: float = 0.5,
        two_pass: bool | None = None,
        cell_capacity: int | None = None,
    ):
        if mesh is None:
            n = jax.device_count()
            mesh = jax.make_mesh(
                (n,), (AXIS,), axis_types=(jax.sharding.AxisType.Auto,)
            )
        self.mesh = mesh
        self.sim = sim
        self.n_parts = mesh.devices.size
        self.cfg = plan_config(
            sim, self.n_parts, n_agents_hint, index=index,
            capacity_factor=capacity_factor, halo_fraction=halo_fraction,
            two_pass=two_pass, cell_capacity=cell_capacity,
        )
        epoch_fn = make_epoch_fn(sim.plan, sim.params, self.cfg)
        pspec = jax.sharding.PartitionSpec
        self._epoch = jax.jit(
            jax.shard_map(
                epoch_fn,
                mesh=mesh,
                in_specs=(
                    pspec(AXIS), pspec(), pspec(), pspec(), pspec(),
                ),
                out_specs=(pspec(AXIS), pspec(AXIS)),
            ),
            donate_argnums=(0,),
        )

    # -- data placement -----------------------------------------------------
    def uniform_bounds(self) -> np.ndarray:
        lo, hi = self.sim.world_lo[0], self.sim.world_hi[0]
        return np.linspace(lo, hi, self.n_parts + 1)

    def distribute(self, state: AgentState, bounds: np.ndarray) -> AgentState:
        """Host-side global partitioning (init / rebalance / restore)."""
        xf = self.sim.plan.visibility.pos_fields[0]
        alive = np.asarray(state.alive)
        x = np.asarray(state.fields[xf])
        k = self.cfg.capacity
        parts = []
        placed = 0
        for p in range(self.n_parts):
            lo_p = -np.inf if p == 0 else bounds[p]
            hi_p = np.inf if p == self.n_parts - 1 else bounds[p + 1]
            inb = alive & (x >= lo_p) & (x < hi_p)
            idx = np.nonzero(inb)[0][:k]
            placed += len(idx)
            part = {
                "alive": np.zeros(k, bool),
                "oid": np.zeros(k, np.int32),
            }
            part["alive"][: len(idx)] = True
            part["oid"][: len(idx)] = np.asarray(state.oid)[idx]
            fields = {}
            for name, arr in state.fields.items():
                a = np.asarray(arr)
                out = np.zeros((k,) + a.shape[1:], a.dtype)
                out[: len(idx)] = a[idx]
                fields[name] = out
            part["fields"] = fields
            parts.append(part)
        total_alive = int(alive.sum())
        if placed < total_alive:
            raise RuntimeError(
                f"partitioning dropped {total_alive - placed} agents "
                f"(per-device capacity {k} too small)"
            )
        glob = AgentState(
            alive=jnp.asarray(np.concatenate([p["alive"] for p in parts])),
            oid=jnp.asarray(np.concatenate([p["oid"] for p in parts])),
            fields={
                name: jnp.asarray(
                    np.concatenate([p["fields"][name] for p in parts])
                )
                for name in parts[0]["fields"]
            },
        )
        sharding = jax.sharding.NamedSharding(
            self.mesh, jax.sharding.PartitionSpec(AXIS)
        )
        return jax.device_put(glob, sharding)

    def gather(self, state: AgentState) -> AgentState:
        """Pull the sharded population back to host memory (epoch boundary)."""
        return jax.tree.map(lambda a: jnp.asarray(jax.device_get(a)), state)

    # -- execution ------------------------------------------------------------
    def run_epoch(
        self,
        state: AgentState,
        bounds: np.ndarray,
        n_ticks: int,
        seed: int = 0,
        t0: int = 0,
    ):
        rng = jax.random.PRNGKey(seed)
        ticks = jnp.arange(n_ticks, dtype=jnp.int32)
        state, stats = self._epoch(
            state,
            jnp.asarray(bounds, jnp.float32),
            rng,
            jnp.asarray(t0, jnp.int32),
            ticks,
        )
        return state, jax.device_get(stats)

"""Single-node BRACE engine: compile a BRASIL class and run epochs of ticks.

The single-node engine is both (a) the baseline used in the paper's
single-node experiments (Figs. 3/4, Table 2) and (b) the oracle against
which the distributed runtime is verified (tests/test_distribute.py).

Ticks inside an epoch are fused with ``lax.scan`` inside a single jitted
call — the in-memory analogue of the paper's "master interacts with workers
only every epoch".
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from typing import TYPE_CHECKING

from . import grid as gridlib

if TYPE_CHECKING:  # avoid a core↔brasil import cycle at runtime
    from ..brasil.fields import AgentClass
from .agents import AgentState, from_numpy
from .tick import TickPlan, make_tick

Array = jax.Array


@dataclasses.dataclass
class Simulation:
    """A compiled simulation: program + world box + parameters."""

    agent_class: "AgentClass"
    plan: TickPlan
    params: dict[str, Any]
    world_lo: tuple[float, float]
    world_hi: tuple[float, float]

    @classmethod
    def build(
        cls,
        agent_class: "AgentClass",
        world_lo: tuple[float, float],
        world_hi: tuple[float, float],
        overrides: dict[str, Any] | None = None,
    ) -> "Simulation":
        from ..brasil.compiler import compile_agent

        params = dict(agent_class.params)
        if overrides:
            unknown = set(overrides) - set(params)
            if unknown:
                raise KeyError(f"unknown params {sorted(unknown)}")
            params.update(overrides)
        plan = compile_agent(agent_class)
        return cls(agent_class, plan, params, tuple(world_lo), tuple(world_hi))

    def init_population(self, capacity: int, oid, **arrays) -> AgentState:
        from ..brasil.compiler import field_specs

        return from_numpy(field_specs(self.agent_class), capacity, oid, **arrays)

    def make_grid(
        self,
        n_agents: int,
        capacity_factor: float = 3.0,
        cell_capacity: int | None = None,
    ) -> gridlib.GridSpec:
        extent = (
            self.world_hi[0] - self.world_lo[0],
            self.world_hi[1] - self.world_lo[1],
        )
        periodic = tuple(p is not None for p in self.plan.visibility.periods)
        return gridlib.make_grid(
            extent,
            self.plan.visibility.bounds,
            n_agents,
            capacity_factor=capacity_factor,
            periodic=periodic,
            cell_capacity=cell_capacity,
        )


class Engine:
    """Single-device driver.  ``index='grid'`` (cell lists) or ``'brute'``."""

    def __init__(
        self,
        sim: Simulation,
        n_agents_hint: int,
        index: str = "grid",
        capacity_factor: float = 3.0,
        cell_capacity: int | None = None,
    ):
        self.sim = sim
        self.index = index
        self.grid_spec = (
            sim.make_grid(n_agents_hint, capacity_factor, cell_capacity)
            if index == "grid"
            else None
        )
        self._tick = make_tick(
            sim.plan, sim.params, self.grid_spec, grid_lo=sim.world_lo
        )
        self._run_jit = jax.jit(self._run, static_argnames=("n_ticks",))

    def _run(self, state: AgentState, rng: Array, t0: Array, n_ticks: int):
        def body(carry, i):
            st = carry
            key = jax.random.fold_in(rng, i)
            st = self._tick(st, key, t0 + i)
            return st, st.num_alive()

        state, alive_counts = jax.lax.scan(
            body, state, jnp.arange(n_ticks, dtype=jnp.int32)
        )
        return state, alive_counts

    def run(self, state: AgentState, n_ticks: int, seed: int = 0, t0: int = 0):
        rng = jax.random.PRNGKey(seed)
        return self._run_jit(state, rng, jnp.asarray(t0, jnp.int32), n_ticks)

    def query_effects(self, state: AgentState):
        """Debug probe: effects after one query phase (no update)."""
        from .tick import query_phase

        return jax.jit(
            partial(query_phase, self.sim.plan, params=self.sim.params, grid_spec=self.grid_spec)
        )(state)


def uniform_population(
    sim: Simulation,
    n: int,
    capacity: int,
    seed: int = 0,
    velocity_scale: float = 0.0,
    extra: dict[str, Any] | None = None,
) -> AgentState:
    """Agents placed uniformly in the world box (convenience for tests)."""
    rs = np.random.RandomState(seed)
    lo, hi = sim.world_lo, sim.world_hi
    xname, yname = sim.agent_class.position
    arrays = {
        xname: rs.uniform(lo[0], hi[0], n).astype(np.float32),
        yname: rs.uniform(lo[1], hi[1], n).astype(np.float32),
    }
    if extra:
        arrays.update({k: np.asarray(v) for k, v in extra.items()})
    return sim.init_population(capacity, oid=np.arange(n), **arrays)

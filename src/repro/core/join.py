"""The spatial self-join driving the query phase (paper §3.1).

Each tick's query phase joins every agent with the agents in its visible
region and aggregates effect assignments with the field combinators.  The
join is expressed over a *candidate table* — either the grid index stencil
(``grid.candidates``) or the quadratic no-index fallback — plus a
visibility predicate evaluated per candidate pair.

Emissions come from the compiled BRASIL program as a ``pair_fn``:

    pair_fn(self_env, other_env, params) ->
        [(target, effect_name, value, cond_mask), ...]

with ``self_env[field] : [N, 1, ...]`` and ``other_env[field] : [N, K, ...]``.
``target == "self"`` contributions are ⊕-reduced over K (local effects);
``target == "other"`` contributions are ⊕-scattered into the candidate's
effect slot (non-local effects — the map-reduce-reduce path, paper §3.2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import combinators as combs
from .agents import AgentState, EffectSpec

Array = jax.Array


def wrapped_delta(d: Array, period: float) -> Array:
    """Shortest signed delta on a circle of the given period."""
    return d - period * jnp.round(d / period)


@dataclasses.dataclass(frozen=True)
class Visibility:
    """Per-axis rectangular visibility bound (the paper's #range boxes),
    optionally intersected with an L2 ball of radius ``radius``.  Periodic
    axes (e.g. a circular road) wrap the distance."""

    pos_fields: tuple[str, str]
    bounds: tuple[float, float]  # half-extent per axis (linf box)
    radius: float | None = None  # optional euclidean bound (<= box)
    periods: tuple[float | None, float | None] = (None, None)

    def deltas(self, self_env: dict, other_env: dict) -> tuple[Array, Array]:
        dx = other_env[self.pos_fields[0]] - self_env[self.pos_fields[0]]
        dy = other_env[self.pos_fields[1]] - self_env[self.pos_fields[1]]
        if self.periods[0] is not None:
            dx = wrapped_delta(dx, self.periods[0])
        if self.periods[1] is not None:
            dy = wrapped_delta(dy, self.periods[1])
        return dx, dy

    def mask(self, self_env: dict, other_env: dict) -> Array:
        dx, dy = self.deltas(self_env, other_env)
        m = (jnp.abs(dx) <= self.bounds[0]) & (jnp.abs(dy) <= self.bounds[1])
        if self.radius is not None:
            m = m & (dx * dx + dy * dy <= self.radius**2)
        return m


def _env_self(fields: dict[str, Array]) -> dict[str, Array]:
    return {k: v[:, None] for k, v in fields.items()}


def _env_other(fields: dict[str, Array], idx: Array) -> dict[str, Array]:
    # idx may contain n (one past the end) for invalid candidates → clip and
    # rely on the validity mask.
    n = next(iter(fields.values())).shape[0]
    safe = jnp.minimum(idx, n - 1)
    return {k: v[safe] for k, v in fields.items()}


def identity_effects(
    effect_specs: list[EffectSpec], n: int
) -> dict[str, Any]:
    """θ — effects reset at the start of every query phase (paper App. A)."""
    out: dict[str, Any] = {}
    for es in effect_specs:
        comb = combs.get(es.comb)
        if isinstance(comb, combs.ArgOptCombinator):
            payload_specs = {p[0]: (tuple(p[1]), p[2]) for p in es.payload}
            single = comb.identity(payload_specs)
            out[es.name] = {
                k: jnp.broadcast_to(v, (n,) + v.shape).astype(v.dtype)
                for k, v in single.items()
            }
        else:
            out[es.name] = comb.identity((n,) + tuple(es.shape), es.dtype)
    return out


def run_query(
    state: AgentState,
    cand_idx: Array,
    cand_valid: Array,
    pair_fn: Callable,
    effect_specs: list[EffectSpec],
    visibility: Visibility,
    params: dict,
    include_self_pair: bool = False,
    self_mask: Array | None = None,
) -> dict[str, Any]:
    """Execute the query phase: returns the per-agent effect values.

    Dead agents neither emit nor receive; an agent is not its own neighbor
    unless ``include_self_pair``.  ``self_mask`` restricts which rows
    *execute* their query (emit) — the distributed runtime passes the
    ownership mask so halo replicas participate only as join candidates,
    exactly the paper's "reducer processes the query phase of its owned
    set" (§3.2); without it, owner and replica would both evaluate the same
    pair and non-local effects would be double-counted.
    """
    n = state.capacity
    spec_by_name = {es.name: es for es in effect_specs}
    effects = identity_effects(effect_specs, n)

    self_env = _env_self(state.fields)
    other_env = _env_other(state.fields, cand_idx)

    alive_self = state.alive[:, None]
    if self_mask is not None:
        alive_self = alive_self & self_mask[:, None]
    alive_other = state.alive[jnp.minimum(cand_idx, n - 1)] & cand_valid
    pair_mask = alive_self & alive_other & visibility.mask(self_env, other_env)
    if not include_self_pair:
        pair_mask = pair_mask & (cand_idx != jnp.arange(n, dtype=cand_idx.dtype)[:, None])

    emissions = pair_fn(self_env, other_env, params)
    for target, name, value, cond in emissions:
        es = spec_by_name[name]
        comb = combs.get(es.comb)
        m = pair_mask if cond is None else (pair_mask & cond)
        if target == "self":
            if isinstance(comb, combs.ArgOptCombinator):
                red = comb.reduce(value, m, axis=1)
                effects[name] = comb.combine(effects[name], red)
            else:
                red = comb.reduce(value, m, axis=1)
                effects[name] = comb.combine(effects[name], red)
        elif target == "other":
            if isinstance(comb, combs.ArgOptCombinator):
                raise NotImplementedError(
                    f"non-local {es.comb} effects unsupported; invert the effect"
                )
            effects[name] = comb.scatter(effects[name], cand_idx, value, m)
        else:  # pragma: no cover
            raise ValueError(f"bad emission target {target!r}")
    return effects


def combine_effects(
    effect_specs: list[EffectSpec],
    a: dict[str, Any],
    b: dict[str, Any],
) -> dict[str, Any]:
    """⊕-merge two partial effect maps (reduce₂ of map-reduce-reduce)."""
    out = {}
    for es in effect_specs:
        comb = combs.get(es.comb)
        out[es.name] = comb.combine(a[es.name], b[es.name])
    return out

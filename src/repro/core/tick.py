"""The state-effect tick: query phase + update phase (paper §2.1).

``make_tick`` assembles a jit-able function advancing a population one tick
on a single partition.  The distributed runtime re-uses the same query and
update phases, inserting halo exchange / effect return between them
(``core/distribute.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import grid as gridlib
from .agents import AgentState, EffectSpec
from .join import Visibility, run_query

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """Everything the runtime needs to execute one agent class's tick.

    Produced by the BRASIL compiler (brasil/compiler.py).
    """

    effect_specs: list[EffectSpec]
    pair_fn: Callable  # (self_env, other_env, params) -> emissions
    update_fn: Callable  # (fields, effects, params, rng, t) -> (fields, alive)
    visibility: Visibility
    reach: tuple[float, float]  # per-axis reachability bound
    has_nonlocal: bool  # any target=="other" emission remains


def query_phase(
    plan: TickPlan,
    state: AgentState,
    params: dict,
    grid_spec: gridlib.GridSpec | None,
    grid_lo: tuple | None = None,
    self_mask: Array | None = None,
) -> dict[str, Any]:
    """Spatial join + effect aggregation.  ``grid_spec=None`` = no index.

    ``grid_lo`` is the (possibly dynamic) grid origin; defaults to (0, 0).
    """
    x = state.fields[plan.visibility.pos_fields[0]]
    y = state.fields[plan.visibility.pos_fields[1]]
    if grid_spec is None:
        cand, valid = gridlib.brute_candidates(state.capacity)
    else:
        lo = (0.0, 0.0) if grid_lo is None else grid_lo
        table, _overflow = gridlib.build_table(grid_spec, lo, x, y, state.alive)
        cand, valid = gridlib.candidates(grid_spec, lo, table, x, y)
    return run_query(
        state,
        cand,
        valid,
        plan.pair_fn,
        plan.effect_specs,
        plan.visibility,
        params,
        self_mask=self_mask,
    )


def update_phase(
    plan: TickPlan,
    state: AgentState,
    effects: dict[str, Any],
    params: dict,
    rng: Array,
    t: Array,
) -> AgentState:
    """Per-agent update rules; may kill agents (alive ← False)."""
    new_fields, new_alive = plan.update_fn(
        state.fields, effects, params, rng, t, oid=state.oid
    )
    # dead agents keep their old fields, frozen
    alive = state.alive & new_alive
    fields = {
        k: jnp.where(
            jnp.reshape(state.alive, state.alive.shape + (1,) * (v.ndim - 1)),
            v,
            state.fields[k],
        )
        for k, v in new_fields.items()
    }
    return AgentState(alive=alive, oid=state.oid, fields=fields)


def make_tick(
    plan: TickPlan,
    params: dict,
    grid_spec: gridlib.GridSpec | None,
    grid_lo: tuple | None = None,
) -> Callable[[AgentState, Array, Array], AgentState]:
    """Single-partition tick: query then update."""

    def tick(state: AgentState, rng: Array, t: Array) -> AgentState:
        effects = query_phase(plan, state, params, grid_spec, grid_lo)
        return update_phase(plan, state, effects, params, rng, t)

    return tick

"""1-D load balancer (paper §3.3 / §5.1).

The paper's prototype: "A one-dimensional load balancer periodically
receives statistics from the slave nodes, including computational load and
number of owned agents; from these it heuristically computes a new
partition trying to balance improved performance against estimated
migration cost."  This is that balancer.

Cost model per slab: ``cost = agents + pair_weight · agents²/width`` (the
query phase is quadratic in local density; pair_weight is measured or left
at a default).  New boundaries invert the piecewise-linear cost CDF, i.e.
equal-cost slabs assuming uniform density within each old slab — the same
granularity of information the paper's master receives (per-slab stats,
not per-agent positions).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BalanceDecision:
    rebalance: bool
    new_bounds: np.ndarray
    imbalance: float          # max/mean cost before
    predicted_imbalance: float
    migration_fraction: float  # estimated fraction of agents changing slab


def slab_costs(counts: np.ndarray, widths: np.ndarray, pair_weight: float = 0.0):
    counts = np.maximum(counts.astype(np.float64), 0.0)
    base = counts.copy()
    if pair_weight > 0:
        dens = counts / np.maximum(widths, 1e-12)
        base = base + pair_weight * counts * dens
    return base


def equal_cost_bounds(
    bounds: np.ndarray, costs: np.ndarray, min_width: float
) -> np.ndarray:
    """Invert the piecewise-linear cost CDF to equal-cost boundaries."""
    p = len(costs)
    total = float(costs.sum())
    if total <= 0:
        return bounds.copy()
    edges = np.asarray(bounds, np.float64)
    cdf = np.concatenate([[0.0], np.cumsum(costs)])
    targets = np.linspace(0.0, total, p + 1)
    new = np.interp(targets, cdf, edges)
    new[0], new[-1] = edges[0], edges[-1]
    # enforce a minimum slab width (halo/migration one-hop soundness)
    for i in range(1, p):
        new[i] = max(new[i], new[i - 1] + min_width)
    for i in range(p - 1, 0, -1):
        new[i] = min(new[i], new[i + 1] - min_width)
    return new


def estimate_migration(
    bounds: np.ndarray, new_bounds: np.ndarray, counts: np.ndarray
) -> float:
    """Fraction of agents changing slab, assuming uniform density per slab."""
    total = float(counts.sum())
    if total <= 0:
        return 0.0
    moved = 0.0
    widths = np.diff(bounds)
    for i in range(len(counts)):
        lo, hi = bounds[i], bounds[i + 1]
        nlo, nhi = new_bounds[i], new_bounds[i + 1]
        stay = max(0.0, min(hi, nhi) - max(lo, nlo))
        frac_stay = stay / max(widths[i], 1e-12)
        moved += counts[i] * (1.0 - min(1.0, frac_stay))
    return moved / total


def decide(
    bounds: np.ndarray,
    counts: np.ndarray,
    min_width: float,
    pair_weight: float = 0.0,
    imbalance_threshold: float = 1.25,
    migration_weight: float = 0.5,
) -> BalanceDecision:
    """Cost/benefit heuristic: rebalance when the imbalance reduction
    outweighs the migration cost (paper: "balancing improved performance
    against estimated migration cost")."""
    bounds = np.asarray(bounds, np.float64)
    counts = np.asarray(counts, np.float64)
    widths = np.diff(bounds)
    costs = slab_costs(counts, widths, pair_weight)
    mean = costs.mean() if costs.size else 0.0
    imbalance = float(costs.max() / mean) if mean > 0 else 1.0

    new_bounds = equal_cost_bounds(bounds, costs, min_width)
    mig = estimate_migration(bounds, new_bounds, counts)

    # predicted post-balance imbalance (re-bin costs onto new bounds)
    pred_costs = _rebin(bounds, costs, new_bounds)
    pmean = pred_costs.mean() if pred_costs.size else 0.0
    predicted = float(pred_costs.max() / pmean) if pmean > 0 else 1.0

    benefit = imbalance - predicted
    go = imbalance > imbalance_threshold and benefit > migration_weight * mig
    return BalanceDecision(
        rebalance=bool(go),
        new_bounds=new_bounds,
        imbalance=imbalance,
        predicted_imbalance=predicted,
        migration_fraction=float(mig),
    )


def _rebin(bounds, costs, new_bounds):
    dens = costs / np.maximum(np.diff(bounds), 1e-12)
    out = np.zeros(len(costs))
    for j in range(len(costs)):
        nlo, nhi = new_bounds[j], new_bounds[j + 1]
        for i in range(len(costs)):
            lo, hi = bounds[i], bounds[i + 1]
            overlap = max(0.0, min(hi, nhi) - max(lo, nlo))
            out[j] += dens[i] * overlap
    return out

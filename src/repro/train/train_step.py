"""Training step: CE loss → grads → AdamW, with optional microbatch
gradient accumulation and (multi-pod) int8 compressed gradient reduction.

The step is a pure function of (state, batch) so the launcher can jit it
with explicit in/out shardings and donate the state (launch/dryrun.py,
launch/train.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.zoo import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1           # gradient accumulation steps
    grad_compression: bool = False  # int8 + error feedback across 'pod'
    pod_axis: str | None = None     # set when running under shard_map


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any        # compute-dtype params
    opt: dict          # fp32 master + moments + step
    err: Any | None    # error-feedback residual (grad compression)


def init_train_state(model: Model, key: Array, train_cfg: TrainConfig) -> TrainState:
    params = model.init(key)
    opt = adamw_init(params)
    err = None
    if train_cfg.grad_compression:
        from .compression import init_error_feedback

        err = init_error_feedback(params)
    return TrainState(params=params, opt=opt, err=err)


def cross_entropy(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


def chunked_ce(hidden: Array, head: Array, labels: Array,
               n_chunks: int = 8, constrain=None) -> Array:
    """CE over sequence chunks: the [B, S, V] logits tensor is never
    materialized (only [B, S/n, V] per chunk, rematerialized in backward).
    Essential at vocab ≥ 50k × seq 4k scales.

    ``constrain(x, dims)`` applies a batch-sharding constraint (dims maps
    array dims → 'batch'/None).  GSPMD does NOT propagate the batch
    sharding into the scan+checkpoint while-loop on its own — it replicates
    the per-chunk logits (measured: 27 GB/device all-gathers on whisper);
    the explicit constraints pin it.  Measured A/B at whisper dims on 256
    devices: scan+ckpt+constraints 6.6 GiB temp vs 51 GiB plain CE.
    """
    b, s, d = hidden.shape
    if s % n_chunks:
        return cross_entropy((hidden @ head).astype(jnp.float32), labels)
    chunk = s // n_chunks
    if constrain is None:
        constrain = lambda x, dims: x

    hs = jnp.moveaxis(hidden.reshape(b, n_chunks, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)
    hs = constrain(hs, (None, "batch", None, None))
    ls = constrain(ls, (None, "batch", None))

    @jax.checkpoint
    def one(carry, inp):
        h, lab = inp  # [B, chunk, D], [B, chunk]
        logits = (h @ head).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, None))
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        return carry - ll.sum(), None

    total, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (hs, ls))
    return total / (b * s)


def make_constrainer(mesh, batch_axes):
    """dims-role → with_sharding_constraint helper for the loss."""
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec

    def constrain(x, dims):
        spec = PartitionSpec(*[batch_axes if r == "batch" else None for r in dims])
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def make_loss_fn(model: Model, mesh=None, batch_axes=None) -> Callable:
    constrain = make_constrainer(mesh, batch_axes)

    def loss_fn(params, batch):
        hidden = model.forward_hidden(params, batch)
        head = model.head_matrix(params)
        return chunked_ce(hidden, head, batch["labels"], constrain=constrain)

    return loss_fn


def make_train_step(model: Model, train_cfg: TrainConfig, mesh=None,
                    batch_axes=None) -> Callable:
    loss_fn = make_loss_fn(model, mesh=mesh, batch_axes=batch_axes)
    param_dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[model.cfg.dtype]

    def grads_of(params, batch):
        if train_cfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l, jax.tree.map(jnp.add, g_acc, g)), None

        n = train_cfg.microbatches
        mbs = jax.tree.map(
            lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(micro, (0.0, zeros), mbs)
        inv = 1.0 / n
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        loss, grads = grads_of(state.params, batch)
        err = state.err
        if train_cfg.grad_compression and train_cfg.pod_axis is not None:
            from .compression import compressed_psum

            grads, err = compressed_psum(grads, train_cfg.pod_axis, err)
        params, opt, metrics = adamw_update(
            train_cfg.optimizer, grads, state.opt, param_dtype
        )
        metrics["loss"] = loss
        return TrainState(params=params, opt=opt, err=err), metrics

    return train_step

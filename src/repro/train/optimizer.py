"""Hand-rolled AdamW with linear-warmup cosine schedule and global-norm
gradient clipping.  Optimizer moments are fp32 regardless of param dtype
(mixed-precision convention: bf16 params for compute, fp32 master copy +
moments in the optimizer state).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params: Any) -> dict:
    """Optimizer state: fp32 master copy + fp32 moments.

    ``jnp.array(..., copy=True)`` (not astype): with f32 params astype is a
    no-op and master would alias params — donating the TrainState then
    donates the same buffer twice.
    """
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True), params)
    return {
        "master": master,
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, opt_state: dict, param_dtype):
    """Returns (new compute params, new opt state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return m_new, v_new, p_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

from .optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .train_step import TrainState, make_train_step, init_train_state  # noqa: F401

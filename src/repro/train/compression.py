"""Gradient compression with error feedback, for the cross-pod reduction.

At multi-pod scale the slowest collective is the gradient all-reduce over
the inter-pod links (DCI), not the intra-pod ICI.  Int8 compression with
per-tensor scales cuts those bytes 4× vs fp32 (2× vs bf16); the error-
feedback accumulator keeps the quantization noise from biasing convergence
(Seide et al. 2014; 1-bit Adam lineage).

Usage inside a train step (under shard_map over the 'pod' axis):
    grads_local = ...                      # already reduced intra-pod
    c, err = compress(grads + err_prev)    # int8 + scales
    c = psum(c, 'pod')                     # the only inter-pod traffic
    grads = decompress(c) / n_pods
This module is exercised numerically in tests/test_train.py and available
via TrainConfig.grad_compression.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def compress(tree: Any) -> tuple[Any, Any, Any]:
    """Per-tensor symmetric int8 quantization.

    Returns (int8 tree, fp32 scales tree, error-feedback residual tree).
    """
    def one(g):
        g = g.astype(jnp.float32)
        scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - q.astype(jnp.float32) * scale
        return q, scale, err

    qs, scales, errs = [], [], []
    leaves, treedef = jax.tree.flatten(tree)
    for g in leaves:
        q, s, e = one(g)
        qs.append(q)
        scales.append(s)
        errs.append(e)
    return (
        treedef.unflatten(qs),
        treedef.unflatten(scales),
        treedef.unflatten(errs),
    )


def decompress(q_tree: Any, scale_tree: Any) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree
    )


def compressed_psum(tree: Any, axis: str, err: Any | None = None):
    """Error-feedback int8 all-reduce over ``axis``.

    ``err`` is the residual carried from the previous step (same structure,
    zeros initially).  Returns (mean-reduced fp32 tree, new residual).
    """
    if err is not None:
        tree = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, tree, err)
    q, scales, new_err = compress(tree)
    # int8 psum would overflow; widen to int32 lanes for the reduction
    q32 = jax.tree.map(lambda a: a.astype(jnp.int32), q)
    q32 = jax.tree.map(lambda a: jax.lax.psum(a, axis), q32)
    # scales are per-pod; reduce with max so dequantization is conservative
    n = jax.lax.psum(1, axis)
    out = jax.tree.map(
        lambda a, s: a.astype(jnp.float32) * s / n, q32, scales
    )
    return out, new_err


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""Attention: GQA with RoPE, streaming-softmax (flash-style) chunked
computation, sliding windows, and decode over KV caches.

Design notes (TPU adaptation):
  * the training/prefill path never materializes the [S, S] score matrix —
    it streams over KV chunks with a running (max, denom, acc) triple, the
    standard flash decomposition, expressed in jnp so XLA fuses it; the
    Pallas kernel (kernels/flash_attention) implements the same tiling
    explicitly for the MXU and is validated against this reference;
  * sliding-window attention is computed on a *statically sized* slice
    (window + chunk) per query chunk (lax.dynamic_slice), so SWA FLOPs are
    O(S·window), not O(S²) — the neighborhood property of the paper applied
    to the sequence axis;
  * layout is head-major after an explicit GQA repeat: KV heads expand to
    the full head count and every intermediate carries a shardable head
    dim.  The repeat is free under tensor parallelism (each device holds
    H/T heads) and lets GSPMD partition the flash transients cleanly —
    the grouped [B,S,Hkv,G,D] layout defeated the partitioner (measured:
    involuntary remat + 100 GiB-class temp buffers on granite-8b).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..dist.activation_sharding import constrain

Array = jax.Array

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, d_head // 2, dtype=jnp.float32) / (d_head // 2))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, S, H, D]; positions: [S] or [B, S]."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _expand_kv(q: Array, k: Array, v: Array):
    g = q.shape[2] // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = constrain(k, ("batch", None, "tensor", None))
    v = constrain(v, ("batch", None, "tensor", None))
    return k, v


# ---------------------------------------------------------------------------
# chunked streaming-softmax attention (train / prefill)
# ---------------------------------------------------------------------------

def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    q_offset: int = 0,
) -> Array:
    """q: [B, S, H, D]; k/v: [B, T, Hkv, D] → [B, S, H, D].

    ``q_offset``: absolute position of q[0] relative to k[0] (chunked
    prefill); causal masking compares absolute positions.

    Differentiable path uses a custom VJP (flash backward): the forward
    saves only (q, k, v, out, lse) and the backward recomputes score
    blocks chunk-by-chunk.  Without it, the scan-based streaming forward
    saves its per-step f32 (p, m, l, acc) residuals — full S×S scores —
    which measured at tens of GiB/device on 4k-seq trains.
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    k, v = _expand_kv(q, k, v)
    q = constrain(q, ("batch", None, "tensor", None))

    if window is not None and window < t:
        return _windowed_attention(q, k, v, window, q_chunk, q_offset)

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    if s % q_chunk or t % kv_chunk:
        q_chunk, kv_chunk = s, t  # tiny/odd shapes: single block

    out = _flash_vjp(
        q, k, v, causal, window, q_chunk, kv_chunk, q_offset
    )
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash core with custom VJP
# ---------------------------------------------------------------------------

def _fwd_streaming(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    """Streaming softmax forward → (out [B,S,H,D] f32, lse [B,H,S] f32)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    nq, nk = s // q_chunk, t // kv_chunk
    qr = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, kv_chunk, h, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kv_chunk, h, d), 1, 0)
    scale = d**-0.5
    q_pos = jnp.arange(s).reshape(nq, q_chunk) + q_offset
    k_pos = jnp.arange(t).reshape(nk, kv_chunk)

    def mask_block(qp, kp):
        m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
        if causal:
            m &= qp[:, None] >= kp[None, :]
        if window is not None:
            m &= qp[:, None] - kp[None, :] < window
        return m

    def per_q_chunk(qi):
        qblk = qr[qi]
        qp = q_pos[qi]

        def body(carry, ki):
            m, l, acc = carry
            sc = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk, kr[ki],
                preferred_element_type=jnp.float32,
            ) * scale
            sc = jnp.where(mask_block(qp, k_pos[ki])[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v.dtype), vr[ki],
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # [B,H,Qc,D]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))          # [B,H,Qc]
        return out, lse

    outs, lses = jax.lax.map(per_q_chunk, jnp.arange(nq))
    out = jnp.transpose(outs, (1, 0, 3, 2, 4)).reshape(b, s, h, d)
    lse = jnp.transpose(lses, (1, 2, 0, 3)).reshape(b, h, s)
    return out, lse


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_vjp(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, _ = _fwd_streaming(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, q_chunk, kv_chunk, q_offset):
    out, lse = _fwd_streaming(q, k, v, causal, window, q_chunk, kv_chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, q_chunk, kv_chunk, q_offset, res, dout):
    """Flash backward: recompute P blocks from (q, k, lse); O(block) memory."""
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    t = k.shape[1]
    nq, nk = s // q_chunk, t // kv_chunk
    scale = d**-0.5

    qr = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, kv_chunk, h, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kv_chunk, h, d), 1, 0)
    dor = jnp.moveaxis(dout.reshape(b, nq, q_chunk, h, d), 1, 0)
    lser = jnp.moveaxis(lse.reshape(b, h, nq, q_chunk), 2, 0)  # [nq,B,H,Qc]
    # D_i = rowsum(dO ∘ O)
    delta = jnp.sum(
        dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # [B, S, H]
    deltar = jnp.moveaxis(
        jnp.transpose(delta, (0, 2, 1)).reshape(b, h, nq, q_chunk), 2, 0
    )  # [nq, B, H, Qc]

    q_pos = jnp.arange(s).reshape(nq, q_chunk) + q_offset
    k_pos = jnp.arange(t).reshape(nk, kv_chunk)

    def mask_block(qp, kp):
        m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
        if causal:
            m &= qp[:, None] >= kp[None, :]
        if window is not None:
            m &= qp[:, None] - kp[None, :] < window
        return m

    def per_kv_chunk(ki):
        kblk = kr[ki]
        vblk = vr[ki]
        kp = k_pos[ki]

        def body(carry, qi):
            dk_acc, dv_acc = carry
            qblk = qr[qi]
            doblk = dor[qi].astype(jnp.float32)
            sc = jnp.einsum(
                "bqhd,bkhd->bhqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            sc = jnp.where(mask_block(q_pos[qi], kp)[None, None], sc, NEG_INF)
            p = jnp.exp(sc - lser[qi][..., None])            # [B,H,Qc,Kc]
            dp = jnp.einsum(
                "bqhd,bkhd->bhqk", doblk, vblk.astype(jnp.float32),
            )
            ds = p * (dp - deltar[qi][..., None]) * scale    # [B,H,Qc,Kc]
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, qblk.astype(jnp.float32))
            dv_acc = dv_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", p, doblk
            )
            dq_blk = jnp.einsum("bhqk,bkhd->bqhd", ds, kblk.astype(jnp.float32))
            return (dk_acc, dv_acc), dq_blk

        z = jnp.zeros((b, kv_chunk, h, d), jnp.float32)
        (dk_j, dv_j), dq_parts = jax.lax.scan(body, (z, z), jnp.arange(nq))
        return dk_j, dv_j, dq_parts  # dq_parts: [nq, B, Qc, H, D]

    dk_js, dv_js, dq_all = jax.lax.map(per_kv_chunk, jnp.arange(nk))
    dk = jnp.moveaxis(dk_js, 0, 1).reshape(b, t, h, d)
    dv = jnp.moveaxis(dv_js, 0, 1).reshape(b, t, h, d)
    # dq: sum over kv chunks → [nq, B, Qc, H, D] → [B, S, H, D]
    dq = jnp.moveaxis(dq_all.sum(axis=0), 0, 1).reshape(b, s, h, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def _windowed_attention(q, k, v, window: int, q_chunk: int, q_offset: int):
    """O(S·window): each query chunk attends to a static (window + chunk)
    KV slice — the sequence-axis neighborhood property."""
    b, s, h, d = q.shape
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    if s % q_chunk:
        q_chunk = s
    nq = s // q_chunk
    span = min(window + q_chunk, t)  # static slice size
    scale = d**-0.5

    qr = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, d), 1, 0)

    def per_q_chunk(qi):
        qblk = qr[qi]
        q_start = qi * q_chunk + q_offset
        start = jnp.clip(q_start - window, 0, max(t - span, 0))
        kblk = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        qp = q_start + jnp.arange(q_chunk)
        kp = start + jnp.arange(span)
        mask = (qp[:, None] >= kp[None, :]) & (qp[:, None] - kp[None, :] < window)
        sc = jnp.einsum(
            "bqhd,bkhd->bhqk", qblk, kblk, preferred_element_type=jnp.float32
        ) * scale
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return out

    outs = jax.lax.map(per_q_chunk, jnp.arange(nq))
    out = jnp.transpose(outs, (1, 0, 3, 2, 4)).reshape(b, s, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode (one new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: Array,           # [B, 1, H, D]
    k_cache: Array,     # [B, T, Hkv, D]
    v_cache: Array,
    pos: Array,         # [] current absolute position
    window: int | None = None,
) -> Array:
    b, _, h, d = q.shape
    t = k_cache.shape[1]
    g = h // k_cache.shape[2]
    scale = d**-0.5
    # GQA via grouped-query reshape (no KV repeat: the cache dominates
    # decode memory traffic and must not be duplicated)
    qg = q.reshape(b, 1, k_cache.shape[2], g, d)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # [B,Hkv,G,1,T]
    idx = jnp.arange(t)
    if window is not None and t == window:
        # ring cache: every written slot is valid once pos >= window
        valid = idx[None, :] <= pos
        wrapped = pos >= window
        mask = jnp.where(wrapped, jnp.ones((1, t), bool), valid)
    else:
        mask = idx[None, :] <= pos
        if window is not None:
            mask = mask & (idx[None, :] > pos - window)
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bhgqd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, d).astype(q.dtype)

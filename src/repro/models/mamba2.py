"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060), chunked.

The selective state space recurrence
    h_t = exp(A·Δ_t) · h_{t-1} + Δ_t · B_t x_t ;   y_t = C_t h_t + D x_t
is computed with the SSD chunk decomposition: intra-chunk (quadratic in the
chunk, runs on the MXU) + inter-chunk state passing (a short scan over
chunks).  This is the standard TPU-friendly formulation; the sequential
variant (``mamba2_decode_step``) serves decode.

Simplifications vs the full Mamba2 block (recorded in DESIGN.md): single
B/C group (n_groups=1), no RMSNorm-gate variant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = jax.Array


def init_mamba2(key, d_model: int, d_state: int, head_dim: int, expand: int,
                d_conv: int, dtype):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 8)
    return {
        # in_proj produces [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner + 2 * d_state), jnp.float32) * 0.2).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),         # A = -exp(A_log)
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "w_out": dense_init(ks[2], d_inner, d_model, dtype),
    }


def _split_proj(proj, d_inner, d_state, n_heads):
    x, z, B, C, dt = jnp.split(
        proj,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    return x, z, B, C, dt


def _causal_conv(u: Array, w: Array) -> Array:
    """Depthwise causal conv1d via shifted adds; u: [B, S, C], w: [K, C]."""
    k = w.shape[0]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        ui = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, : u.shape[1]]
        out = out + ui.astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(u.dtype)


def mamba2_forward(
    x_in: Array,  # [B, S, D]
    p: dict,
    *,
    d_state: int,
    head_dim: int,
    expand: int,
    chunk: int = 128,
) -> Array:
    b, s, d = x_in.shape
    d_inner = expand * d
    n_heads = d_inner // head_dim

    from ..dist.activation_sharding import constrain as _constrain

    proj = x_in @ p["w_in"]
    x, z, B, C, dt = _split_proj(proj, d_inner, d_state, n_heads)
    # pin the clean d_inner tensors to the tensor axis (the concatenated
    # proj has split points that cross shard boundaries — constraining it
    # directly would force resharding gathers)
    x = _constrain(x, ("batch", None, "tensor"))
    z = _constrain(z, ("batch", None, "tensor"))
    xbc = jnp.concatenate([x, B, C], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"]))
    x, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    x = _constrain(x, ("batch", None, "tensor"))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H]
    xh = x.reshape(b, s, n_heads, head_dim)

    # The SSD recurrence is sequential along S, so the sequence axis cannot
    # stay sharded here — instead the computation is embarrassingly
    # parallel over HEADS: pin the head dim to the tensor axis so the f32
    # chunk transients ([B,L,L,H] decay etc.) shard 16× instead of being
    # gathered whole (measured: 57 GiB → fits on zamba2 train).
    from ..dist.activation_sharding import constrain

    xh = constrain(xh, ("batch", None, "tensor", None))
    dt = constrain(dt, ("batch", None, "tensor"))

    y = _ssd_chunked(
        xh.astype(jnp.float32), dt, A,
        B.astype(jnp.float32), C.astype(jnp.float32), chunk,
    )
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = constrain(y, ("batch", None, "tensor", None))
    y = y.reshape(b, s, d_inner).astype(x_in.dtype)
    z = constrain(z, ("batch", None, "tensor"))
    y = y * jax.nn.silu(z)
    return y @ p["w_out"]


def _ssd_chunked(x, dt, A, B, C, chunk: int):
    """x: [B,S,H,P] f32; dt: [B,S,H]; A: [H]; B/C: [B,S,N] → y [B,S,H,P].

    One lax.scan over chunks (carry = inter-chunk state [B,H,N,P]) keeps the
    [L,L,H] intra-chunk decay tensor bounded to a single chunk.
    """
    b, s, h, pdim = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    xr = jnp.moveaxis(x.reshape(b, nc, chunk, h, pdim), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0)
    Br = jnp.moveaxis(B.reshape(b, nc, chunk, n), 1, 0)
    Cr = jnp.moveaxis(C.reshape(b, nc, chunk, n), 1, 0)

    def scan_fn(s_prev, inp):
        x_c, dt_c, b_c, c_c = inp  # [B,L,H,P], [B,L,H], [B,L,N], [B,L,N]
        dA = dt_c * A[None, None, :]          # [B,L,H], negative
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1, :]                 # [B,H]

        # intra-chunk
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,L,M,H]
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("bln,bmn->blm", c_c, b_c)                 # [B,L,M]
        w = cb[..., None] * decay * dt_c[:, None, :, :]           # [B,L,M,H]
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, x_c)

        # inter-chunk contribution from the carried state
        y_inter = jnp.einsum("bln,blh,bhnp->blhp", c_c, jnp.exp(cum), s_prev)

        # new carry
        sw = jnp.exp(total[:, None, :] - cum) * dt_c              # [B,L,H]
        s_c = jnp.einsum("bln,blh,blhp->bhnp", b_c, sw, x_c)
        s_new = jnp.exp(total)[:, :, None, None] * s_prev + s_c
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    _, ys = jax.lax.scan(scan_fn, s0, (xr, dtr, Br, Cr))
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, pdim)


# ---------------------------------------------------------------------------
# decode (single-token recurrence)
# ---------------------------------------------------------------------------

def mamba2_init_cache(batch: int, p: dict, *, d_model: int, d_state: int,
                      head_dim: int, expand: int, d_conv: int, dtype):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner + 2 * d_state), dtype),
        "ssm": jnp.zeros((batch, n_heads, d_state, head_dim), jnp.float32),
    }


def mamba2_decode_step(
    x_in: Array,  # [B, 1, D]
    cache: dict,
    p: dict,
    *,
    d_state: int,
    head_dim: int,
    expand: int,
):
    b, _, d = x_in.shape
    d_inner = expand * d
    n_heads = d_inner // head_dim

    proj = x_in[:, 0] @ p["w_in"]
    x, z, B, C, dt = _split_proj(proj, d_inner, d_state, n_heads)
    xbc = jnp.concatenate([x, B, C], axis=-1)  # [B, C_in]
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # [B, K, C]
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
    )
    xbc = jax.nn.silu(conv_out).astype(x_in.dtype)
    x, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(b, n_heads, head_dim).astype(jnp.float32)

    da = jnp.exp(dt * A[None, :])  # [B,H]
    s_new = (
        cache["ssm"] * da[:, :, None, None]
        + jnp.einsum("bn,bh,bhp->bhnp", B.astype(jnp.float32), dt, xh)
    )
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), s_new)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, d_inner).astype(x_in.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["w_out"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": s_new}

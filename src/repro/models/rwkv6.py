"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
**data-dependent decay** (the architecture's headline feature), computed in
chunked parallel form, plus the squared-ReLU channel-mix FFN.

Per head (size K=V): state S ∈ R^{K×V};
    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ,   w_t = exp(-exp(w0 + LoRA(x_t)))

Chunked evaluation mirrors the SSD trick: within a chunk the lower-
triangular decay products form an attention-like matrix (MXU-friendly);
across chunks a scan carries S.  The Pallas kernel (kernels/rwkv6) tiles
exactly this computation; this module is its jnp reference semantics.

Simplification vs the full Finch block (see DESIGN.md): token-shift uses
learned static mix coefficients (the data-dependent ddlerp is elided); the
decay LoRA — the part that makes RWKV6 RWKV6 — is kept.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = jax.Array


def init_rwkv6(key, d: int, d_ff: int, head_size: int, dtype, lora_r: int = 64):
    ks = jax.random.split(key, 12)
    h = d // head_size
    return {
        "ln1": {"w": jnp.ones((d,), dtype)},
        "ln2": {"w": jnp.ones((d,), dtype)},
        # time-mix
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + (tanh(x A)) B))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": dense_init(ks[5], d, lora_r, dtype),
        "wB": dense_init(ks[6], lora_r, d, dtype, scale=0.01),
        "u": (jax.random.normal(ks[7], (h, head_size), jnp.float32) * 0.1),
        # channel-mix
        "cmix_k": jnp.full((d,), 0.5, dtype),
        "ck": dense_init(ks[8], d, d_ff, dtype),
        "cv": dense_init(ks[9], d_ff, d, dtype),
    }


def _token_shift(x: Array, prev: Array | None = None) -> Array:
    """x[t-1] (zeros / cache for t=0); x: [B, S, D]."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x * mu + xs * (1.0 - mu)


def _decay(xw: Array, p: dict) -> Array:
    """Data-dependent per-channel decay in (0,1); returns log-decay [.., D]."""
    lora = jnp.tanh(xw @ p["wA"]) @ p["wB"]
    return -jnp.exp(p["w0"] + lora.astype(jnp.float32))  # log w_t ≤ 0


def rwkv6_time_mix(
    x: Array,  # [B, S, D] (already ln1-normed)
    p: dict,
    head_size: int,
    shift_state: Array | None = None,
    wkv_state: Array | None = None,
    chunk: int = 64,
):
    """Returns (out [B,S,D], new_shift [B,D], new_wkv [B,H,K,V])."""
    b, s, d = x.shape
    h = d // head_size
    xs = _token_shift(x, shift_state)
    r = _mix(x, xs, p["mix_r"]) @ p["wr"]
    k = _mix(x, xs, p["mix_k"]) @ p["wk"]
    v = _mix(x, xs, p["mix_v"]) @ p["wv"]
    g = _mix(x, xs, p["mix_g"]) @ p["wg"]
    logw = _decay(_mix(x, xs, p["mix_w"]), p)  # [B,S,D] f32

    rh = r.reshape(b, s, h, head_size).astype(jnp.float32)
    kh = k.reshape(b, s, h, head_size).astype(jnp.float32)
    vh = v.reshape(b, s, h, head_size).astype(jnp.float32)
    wh = logw.reshape(b, s, h, head_size)

    s0 = (
        wkv_state
        if wkv_state is not None
        else jnp.zeros((b, h, head_size, head_size), jnp.float32)
    )
    out, s_new = _wkv_chunked(rh, kh, vh, wh, p["u"], s0, chunk)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = out * jax.nn.silu(g)
    return out @ p["wo"], x[:, -1], s_new


def _wkv_chunked(r, k, v, logw, u, s0, chunk: int):
    """r/k/v: [B,S,H,K] f32; logw: [B,S,H,K]; u: [H,K]; s0: [B,H,K,V].

    Within a chunk:
      out_t = r_t·( prod(w_{<t in chunk}) ⊙ S_in
                    + Σ_{m<t} (prod_{m<j≤t-1} w_j) ⊙ k_m v_mᵀ
                    + diag(u) k_t v_tᵀ )
    """
    b, s, h, kd = r.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    nc = s // chunk
    tri_lo = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly lower

    rr = jnp.moveaxis(r.reshape(b, nc, chunk, h, kd), 1, 0)
    kk = jnp.moveaxis(k.reshape(b, nc, chunk, h, kd), 1, 0)
    vv = jnp.moveaxis(v.reshape(b, nc, chunk, h, kd), 1, 0)
    ww = jnp.moveaxis(logw.reshape(b, nc, chunk, h, kd), 1, 0)

    def scan_fn(s_prev, inp):
        r_c, k_c, v_c, w_c = inp  # [B,L,H,K]
        cum = jnp.cumsum(w_c, axis=1)            # [B,L,H,K] log prod w_{≤t}
        # decay from position m (exclusive) to t (inclusive-of-t? define):
        # prod_{j=m+1..t} w_j = exp(cum_t - cum_m)
        # carry-in contribution at t uses prod_{j=1..t} w_j / w_t? — the
        # state BEFORE t has absorbed w up to t-1: exp(cum_{t-1}) = cum_t - w_t
        cum_excl = cum - w_c                      # log prod w_{<t}
        # inter: out_inter_t = r_t · (exp(cum_excl_t) ⊙ S_prev)
        rd = r_c * jnp.exp(cum_excl)              # [B,L,H,K]
        out_inter = jnp.einsum("blhk,bhkv->blhv", rd, s_prev)

        # intra (m < t): weight_tm = r_t ⊙ exp(cum_excl_t - cum_m) · k_m
        # att[b,l,m,h] = Σ_k r[l] exp(cum_excl[l]-cum[m]) k[m]
        att = jnp.einsum(
            "blhk,bmhk->blmh",
            r_c * jnp.exp(cum_excl),
            k_c * jnp.exp(-cum),
        )
        att = jnp.where(tri_lo[None, :, :, None], att, 0.0)
        out_intra = jnp.einsum("blmh,bmhv->blhv", att, v_c)

        # diagonal bonus term: r_t · (u ⊙ k_t) v_tᵀ
        diag = jnp.einsum("blhk,hk,blhk->blh", r_c, u, k_c)
        out_diag = diag[..., None] * v_c

        # new state: S = exp(cum_L) ⊙ S_prev + Σ_m exp(cum_L - cum_m) k_m v_mᵀ
        total = cum[:, -1]                        # [B,H,K]
        s_new = jnp.exp(total)[..., None] * s_prev + jnp.einsum(
            "blhk,blhv->bhkv", k_c * jnp.exp(total[:, None] - cum), v_c
        )
        return s_new, out_inter + out_intra + out_diag

    s_fin, ys = jax.lax.scan(scan_fn, s0, (rr, kk, vv, ww))
    out = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, kd)
    return out, s_fin


def rwkv6_channel_mix(x: Array, p: dict, shift_state: Array | None = None):
    xs = _token_shift(x, shift_state)
    xk = _mix(x, xs, p["cmix_k"])
    hidden = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return hidden @ p["cv"], x[:, -1]


def rwkv6_block(x: Array, p: dict, head_size: int, norm_eps: float = 1e-5):
    """Full block for training/prefill (no cache)."""
    from .layers import rmsnorm

    a, _, _ = rwkv6_time_mix(rmsnorm(x, p["ln1"]["w"], norm_eps), p, head_size)
    x = x + a
    c, _ = rwkv6_channel_mix(rmsnorm(x, p["ln2"]["w"], norm_eps), p)
    return x + c


def rwkv6_init_cache(batch: int, d: int, head_size: int, dtype):
    h = d // head_size
    return {
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, head_size, head_size), jnp.float32),
    }


def rwkv6_decode_step(x: Array, cache: dict, p: dict, head_size: int,
                      norm_eps: float = 1e-5):
    """x: [B, 1, D] → (out, new_cache)."""
    from .layers import rmsnorm

    xn = rmsnorm(x, p["ln1"]["w"], norm_eps)
    a, shift_t, wkv = rwkv6_time_mix(
        xn, p, head_size, shift_state=cache["shift_t"], wkv_state=cache["wkv"],
        chunk=1,
    )
    x = x + a
    xn = rmsnorm(x, p["ln2"]["w"], norm_eps)
    c, shift_c = rwkv6_channel_mix(xn, p, shift_state=cache["shift_c"])
    x = x + c
    return x, {"shift_t": shift_t, "shift_c": shift_c, "wkv": wkv}

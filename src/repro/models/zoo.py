"""Model zoo façade: uniform (init / forward / prefill / decode) API over
all families, dispatched on ArchConfig."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import encdec, transformer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[Array], dict]
    forward: Callable[[dict, Any], Array]           # (params, batch) → logits
    forward_hidden: Callable[[dict, Any], Array]    # (params, batch) → [B,S,D]
    prefill: Callable[..., tuple]                   # (params, batch, max_len)
    decode_step: Callable[..., tuple]               # (params, cache, token, pos)
    init_cache: Callable[..., dict]

    def head_matrix(self, params: dict) -> Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            forward=lambda p, batch: encdec.forward(cfg, p, batch),
            forward_hidden=lambda p, batch: encdec.forward_hidden(cfg, p, batch),
            prefill=lambda p, batch, max_len: encdec.prefill(cfg, p, batch, max_len),
            decode_step=lambda p, cache, tok, pos: encdec.decode_step(cfg, p, cache, tok, pos),
            init_cache=lambda batch, max_len, s_enc: encdec.init_cache(cfg, batch, max_len, s_enc),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        forward=lambda p, batch: transformer.forward(
            cfg, p, batch["tokens"] if isinstance(batch, dict) else batch
        ),
        forward_hidden=lambda p, batch: transformer.forward_hidden(
            cfg, p, batch["tokens"] if isinstance(batch, dict) else batch
        ),
        prefill=lambda p, batch, max_len: transformer.prefill(
            cfg, p, batch["tokens"] if isinstance(batch, dict) else batch, max_len
        ),
        decode_step=lambda p, cache, tok, pos: transformer.decode_step(cfg, p, cache, tok, pos),
        init_cache=lambda batch, max_len, s_enc=None: transformer.init_cache(cfg, batch, max_len),
    )

"""Decoder-only LM covering the dense / MoE / hybrid(Zamba2) / SSM(RWKV6)
families, driven entirely by ArchConfig.

Layer parameters are stacked along a leading L axis and iterated with
``lax.scan`` (+ optional remat) so 80-layer configs compile in one layer's
HLO.  Zamba2's tied shared-attention block runs between scan segments so
its KV caches stay at n_applications (not n_layers) granularity.

Three entry points per model, all pure functions of (cfg, params, ...):
    forward      — training/scoring logits over a full sequence
    prefill      — run the prompt, build decode caches
    decode_step  — one token against the caches (ring buffers for SWA)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..dist.activation_sharding import constrain
from . import attention as attn
from . import mamba2 as m2
from . import moe as moelib
from . import rwkv6 as rwkv
from .layers import dense_init, dtype_of, embed_init, init_mlp, make_norm, mlp

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    norm_init, _ = make_norm(cfg.norm)
    p: dict[str, Any] = {
        "ln1": norm_init(ks[0], d, dtype),
        "ln2": norm_init(ks[1], d, dtype),
        "wq": dense_init(ks[2], d, cfg.n_heads * cfg.d_head, dtype),
        "wk": dense_init(ks[3], d, cfg.n_kv_heads * cfg.d_head, dtype),
        "wv": dense_init(ks[4], d, cfg.n_kv_heads * cfg.d_head, dtype),
        "wo": dense_init(ks[5], cfg.n_heads * cfg.d_head, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
    if cfg.qk_norm:
        p["qn"] = jnp.ones((cfg.d_head,), dtype)
        p["kn"] = jnp.ones((cfg.d_head,), dtype)
    if cfg.is_moe:
        p["moe"] = moelib.init_moe(
            ks[6], d, cfg.d_expert, cfg.n_experts, cfg.n_shared_experts, dtype
        )
    else:
        p["mlp"] = init_mlp(ks[7], d, cfg.d_ff, cfg.act, dtype)
    return p


def _init_block(key, cfg: ArchConfig, dtype):
    if cfg.family == "ssm":
        return rwkv.init_rwkv6(key, cfg.d_model, cfg.d_ff, cfg.rwkv_head_size, dtype)
    if cfg.family == "hybrid":
        norm_init, _ = make_norm(cfg.norm)
        ks = jax.random.split(key, 2)
        return {
            "ln": norm_init(ks[0], cfg.d_model, dtype),
            "mamba": m2.init_mamba2(
                ks[1], cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim,
                cfg.ssm_expand, cfg.ssm_conv, dtype,
            ),
        }
    return _init_attn_block(key, cfg, dtype)


def init_params(cfg: ArchConfig, key: Array) -> dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 5)
    norm_init, _ = make_norm(cfg.norm)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_layers)
    )
    p = {
        "embed": embed_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": norm_init(ks[2], cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[3], cfg.d_model, cfg.vocab, dtype, scale=0.02)
    if cfg.family == "hybrid":
        p["shared"] = _init_attn_block(ks[4], cfg, dtype)  # tied weights
    return p


# ---------------------------------------------------------------------------
# blocks (full-sequence)
# ---------------------------------------------------------------------------

def _attention_block(x, p, cfg: ArchConfig, positions, q_offset: int = 0,
                     kv=None):
    """Pre-norm attention + FFN block.  ``kv`` overrides K/V source (cache)."""
    _, norm_apply = make_norm(cfg.norm)
    x = constrain(x, ("batch", "seq", None))  # sequence-parallel residuals
    b, s, d = x.shape
    h = norm_apply(x, p["ln1"], cfg.norm_eps)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        from .layers import rmsnorm

        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    o = attn.flash_attention(
        q, k, v, causal=True, window=cfg.window, q_offset=q_offset
    )
    x = x + o.reshape(b, s, -1) @ p["wo"]
    h2 = norm_apply(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y = moelib.moe_ffn(
            h2, p["moe"], n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        y = mlp(h2, p["mlp"], cfg.act)
    # pin the block OUTPUT as well: the scan-over-layers backward carries
    # this tensor's cotangent between iterations, and without an exit
    # constraint GSPMD may resolve the carry as replicated (24 GiB f32 on
    # mixtral; dense models happened to propagate fine)
    out = constrain(x + y, ("batch", "seq", None))
    return out, (k, v)


def _mamba_block(x, p, cfg: ArchConfig):
    _, norm_apply = make_norm(cfg.norm)
    x = constrain(x, ("batch", "seq", None))
    h = norm_apply(x, p["ln"], cfg.norm_eps)
    y = m2.mamba2_forward(
        h, p["mamba"], d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
        expand=cfg.ssm_expand,
    )
    return x + y


# ---------------------------------------------------------------------------
# forward (train / score)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    # Full recompute (no saveable policy): `dots_with_no_batch_dims_saveable`
    # classifies every activation matmul as saveable (a plain [T,D]@[D,F]
    # dot has no dot-general batch dims) and pinned 4×[L,B,S,d_ff] f32
    # buffers — 32 GiB/device on granite-8b.  Saving only layer inputs
    # costs one extra forward (the standard ~33% remat overhead).
    if cfg.remat:
        return jax.checkpoint(fn)
    return fn


def forward(cfg: ArchConfig, params: dict, tokens: Array) -> Array:
    """tokens: [B, S] int32 → logits [B, S, V]."""
    x = forward_hidden(cfg, params, tokens)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ head).astype(jnp.float32)


def forward_hidden(cfg: ArchConfig, params: dict, tokens: Array) -> Array:
    """tokens: [B, S] int32 → final-norm hidden states [B, S, D].

    The training loss projects these through the LM head in sequence
    chunks (train/train_step.py) so the [B, S, V] logits tensor is never
    materialized.
    """
    _, norm_apply = make_norm(cfg.norm)
    x = params["embed"][tokens]
    b, s, d = x.shape
    positions = jnp.arange(s)

    if cfg.family == "ssm":
        def block(x, blk):
            x = constrain(x, ("batch", "seq", None))
            return rwkv.rwkv6_block(x, blk, cfg.rwkv_head_size, cfg.norm_eps), None

        block = _maybe_remat(block, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(block, x, params["blocks"])
        else:
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = block(x, blk)

    elif cfg.family == "hybrid":
        period = max(1, cfg.shared_attn_period)

        def mamba_step(x, blk):
            return _mamba_block(x, blk, cfg), None

        mamba_step = _maybe_remat(mamba_step, cfg)
        shared_fn = _maybe_remat(
            lambda x: _attention_block(x, params["shared"], cfg, positions)[0],
            cfg,
        )
        n_seg, rem = divmod(cfg.n_layers, period)
        layer = 0
        for seg in range(n_seg):
            seg_blocks = jax.tree.map(
                lambda a: a[layer:layer + period], params["blocks"]
            )
            if cfg.scan_layers:
                x, _ = jax.lax.scan(mamba_step, x, seg_blocks)
            else:
                for i in range(period):
                    x, _ = mamba_step(x, jax.tree.map(lambda a: a[i], seg_blocks))
            x = shared_fn(x)
            layer += period
        for i in range(rem):
            x, _ = mamba_step(x, jax.tree.map(lambda a: a[layer + i], params["blocks"]))

    else:  # dense / moe
        def block(x, blk):
            out, _ = _attention_block(x, blk, cfg, positions)
            return out, None

        block = _maybe_remat(block, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(block, x, params["blocks"])
        else:
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = block(x, blk)

    return norm_apply(x, params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# serve: prefill + decode
# ---------------------------------------------------------------------------

def cache_len(cfg: ArchConfig, max_len: int) -> int:
    if cfg.window is not None:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """All-zeros decode cache (prefill fills it)."""
    dtype = dtype_of(cfg.dtype)
    t = cache_len(cfg, max_len)
    if cfg.family == "ssm":
        caches = jax.vmap(
            lambda _: rwkv.rwkv6_init_cache(batch, cfg.d_model, cfg.rwkv_head_size, dtype)
        )(jnp.arange(cfg.n_layers))
        return {"rwkv": caches}
    if cfg.family == "hybrid":
        n_app = cfg.n_layers // max(1, cfg.shared_attn_period)
        mamba = jax.vmap(
            lambda _: m2.mamba2_init_cache(
                batch, {}, d_model=cfg.d_model, d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
                d_conv=cfg.ssm_conv, dtype=dtype,
            )
        )(jnp.arange(cfg.n_layers))
        kv = {
            "k": jnp.zeros((n_app, batch, t, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((n_app, batch, t, cfg.n_kv_heads, cfg.d_head), dtype),
        }
        return {"mamba": mamba, "kv": kv}
    return {
        "k": jnp.zeros((cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, t, cfg.n_kv_heads, cfg.d_head), dtype),
    }


def _ring_slots(positions: Array, t: int) -> Array:
    return jnp.mod(positions, t)


def _write_kv(cache_k, cache_v, k, v, positions, t):
    """Scatter K/V rows at ring slots; k: [B, S, Hkv, D]."""
    slots = _ring_slots(positions, t)
    ck = cache_k.at[:, slots].set(jnp.moveaxis(k, 1, 1))
    cv = cache_v.at[:, slots].set(v)
    return ck, cv


def prefill(cfg: ArchConfig, params: dict, tokens: Array, max_len: int):
    """Run the prompt; returns (last-token logits [B, V], cache, pos)."""
    _, norm_apply = make_norm(cfg.norm)
    b, s = tokens.shape
    t = cache_len(cfg, max_len)
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    cache = init_cache(cfg, b, max_len)

    if cfg.family == "ssm":
        def block(x, blk_and_cache):
            blk, _ = blk_and_cache
            from .layers import rmsnorm

            h = rmsnorm(x, blk["ln1"]["w"], cfg.norm_eps)
            a, shift_t, wkv = rwkv.rwkv6_time_mix(h, blk, cfg.rwkv_head_size)
            x = x + a
            h2 = rmsnorm(x, blk["ln2"]["w"], cfg.norm_eps)
            c, shift_c = rwkv.rwkv6_channel_mix(h2, blk)
            x = x + c
            return x, {"shift_t": shift_t, "shift_c": shift_c, "wkv": wkv}

        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(block, x, (params["blocks"], cache["rwkv"]))
        else:
            outs = []
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                c = jax.tree.map(lambda a: a[i], cache["rwkv"])
                x, nc = block(x, (blk, c))
                outs.append(nc)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache = {"rwkv": new_caches}

    elif cfg.family == "hybrid":
        period = max(1, cfg.shared_attn_period)
        n_seg = cfg.n_layers // period
        rem = cfg.n_layers - n_seg * period
        mamba_caches = []

        def mamba_prefill(x, blk):
            _, norm_apply2 = make_norm(cfg.norm)
            h = norm_apply2(x, blk["ln"], cfg.norm_eps)
            # full forward + terminal state via chunked scan, then rebuild
            # terminal cache with a tail pass (cheap: one decode-form step
            # would need the running state; we recompute states chunked)
            y, conv_state, ssm_state = _mamba_prefill_with_state(h, blk["mamba"], cfg)
            return x + y, {"conv": conv_state, "ssm": ssm_state}

        layer = 0
        kv_k, kv_v = [], []
        for seg in range(n_seg):
            for i in range(period):
                blk = jax.tree.map(lambda a: a[layer], params["blocks"])
                x, mc = mamba_prefill(x, blk)
                mamba_caches.append(mc)
                layer += 1
            x, (k, v) = _attention_block(x, params["shared"], cfg, positions)
            kv_k.append(k)
            kv_v.append(v)
        for i in range(rem):
            blk = jax.tree.map(lambda a: a[layer], params["blocks"])
            x, mc = mamba_prefill(x, blk)
            mamba_caches.append(mc)
            layer += 1

        mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_caches)
        ck = cache["kv"]["k"]
        cv = cache["kv"]["v"]
        for a_i, (k, v) in enumerate(zip(kv_k, kv_v)):
            ks, vs = _tail_ring(k, v, t, s)
            ck = ck.at[a_i].set(ks)
            cv = cv.at[a_i].set(vs)
        cache = {"mamba": mamba, "kv": {"k": ck, "v": cv}}

    else:
        def block(x, blk_and_cache):
            blk, c = blk_and_cache
            x, (k, v) = _attention_block(x, blk, cfg, positions)
            ks, vs = _tail_ring(k, v, t, s)
            return x, {"k": ks, "v": vs}

        if cfg.scan_layers:
            x, new_kv = jax.lax.scan(
                block, x, (params["blocks"], {"k": cache["k"], "v": cache["v"]})
            )
        else:
            outs = []
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                c = {"k": cache["k"][i], "v": cache["v"][i]}
                x, nc = block(x, (blk, c))
                outs.append(nc)
            new_kv = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        cache = new_kv

    x = norm_apply(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, cache, jnp.asarray(s, jnp.int32)


def _tail_ring(k: Array, v: Array, t: int, s: int):
    """Store the last t positions of k/v ([B,S,H,D]) ring-aligned."""
    if s <= t:
        pad = t - s
        ks = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return ks, vs
    tail_k = k[:, s - t:]
    tail_v = v[:, s - t:]
    slots = jnp.mod(jnp.arange(s - t, s), t)
    ks = jnp.zeros_like(tail_k).at[:, slots].set(tail_k)
    vs = jnp.zeros_like(tail_v).at[:, slots].set(tail_v)
    return ks, vs


def _mamba_prefill_with_state(h, p, cfg: ArchConfig):
    """Forward a full prompt AND return terminal (conv, ssm) states."""
    b, s, d = h.shape
    d_inner = cfg.ssm_expand * d
    n_heads = d_inner // cfg.ssm_head_dim

    proj = h @ p["w_in"]
    x, z, B, C, dt = m2._split_proj(proj, d_inner, cfg.ssm_state, n_heads)
    xbc = jnp.concatenate([x, B, C], axis=-1)
    conv_state = xbc[:, -(cfg.ssm_conv - 1):]  # terminal conv window
    xbc = jax.nn.silu(m2._causal_conv(xbc, p["conv_w"]))
    x, B, C = jnp.split(xbc, [d_inner, d_inner + cfg.ssm_state], axis=-1)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(b, s, n_heads, cfg.ssm_head_dim).astype(jnp.float32)

    y = m2._ssd_chunked(xh, dtp, A, B.astype(jnp.float32), C.astype(jnp.float32), 128)
    # terminal ssm state: run the chunk recurrence once more over all steps
    ssm_state = _terminal_state(xh, dtp, A, B.astype(jnp.float32))
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(h.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["w_out"], conv_state, ssm_state


def _terminal_state(x, dt, A, B):
    """S_T = Σ_m exp(Σ_{j>m} dA_j) dt_m B_m x_mᵀ (f32)."""
    dA = dt * A[None, None, :]
    cum = jnp.cumsum(dA, axis=1)
    total = cum[:, -1:, :]
    w = jnp.exp(total - cum) * dt  # [B,S,H]
    return jnp.einsum("bsn,bsh,bshp->bhnp", B, w, x)


def decode_step(cfg: ArchConfig, params: dict, cache: dict, token: Array,
                pos: Array):
    """token: [B] int32; pos: [] int32 → (logits [B, V], new cache)."""
    _, norm_apply = make_norm(cfg.norm)
    x = params["embed"][token][:, None]  # [B, 1, D]
    b = x.shape[0]
    positions = pos[None].astype(jnp.int32)  # [1]

    if cfg.family == "ssm":
        def block(x, blk_and_cache):
            blk, c = blk_and_cache
            x, new_c = rwkv.rwkv6_decode_step(x, c, blk, cfg.rwkv_head_size, cfg.norm_eps)
            return x, new_c

        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(block, x, (params["blocks"], cache["rwkv"]))
        else:
            outs = []
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                c = jax.tree.map(lambda a: a[i], cache["rwkv"])
                x, nc = block(x, (blk, c))
                outs.append(nc)
            new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = {"rwkv": new_caches}

    elif cfg.family == "hybrid":
        period = max(1, cfg.shared_attn_period)
        n_app = cfg.n_layers // period
        t = cache["kv"]["k"].shape[2]

        def mamba_step(x, blk_and_cache):
            blk, c = blk_and_cache
            h = norm_apply(x, blk["ln"], cfg.norm_eps)
            y, new_c = m2.mamba2_decode_step(
                h, c, blk["mamba"], d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            )
            return x + y, new_c

        def run_segment(x, lo, hi):
            seg_blocks = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
            seg_cache = jax.tree.map(lambda a: a[lo:hi], cache["mamba"])
            if cfg.scan_layers:
                return jax.lax.scan(mamba_step, x, (seg_blocks, seg_cache))
            outs = []
            for i in range(hi - lo):
                blk = jax.tree.map(lambda a: a[i], seg_blocks)
                c = jax.tree.map(lambda a: a[i], seg_cache)
                x, nc = mamba_step(x, (blk, c))
                outs.append(nc)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        new_mamba = []
        ck, cv = cache["kv"]["k"], cache["kv"]["v"]
        layer = 0
        for app in range(n_app):
            x, seg_new = run_segment(x, layer, layer + period)
            new_mamba.append(seg_new)
            x, ck, cv = _decode_attn(
                x, params["shared"], cfg, ck, cv, app, pos, t
            )
            layer += period
        rem = cfg.n_layers - layer
        if rem:
            x, seg_new = run_segment(x, layer, cfg.n_layers)
            new_mamba.append(seg_new)
        mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_mamba)
        new_cache = {"mamba": mamba, "kv": {"k": ck, "v": cv}}

    else:
        t = cache["k"].shape[2]

        def block(x, blk_and_cache):
            blk, c = blk_and_cache
            x, ck, cv = _decode_attn_rows(x, blk, cfg, c["k"], c["v"], pos, t)
            return x, {"k": ck, "v": cv}

        if cfg.scan_layers:
            x, new_cache = jax.lax.scan(block, x, (params["blocks"], cache))
        else:
            outs = []
            for i in range(cfg.n_layers):
                blk = jax.tree.map(lambda a: a[i], params["blocks"])
                c = jax.tree.map(lambda a: a[i], cache)
                x, nc = block(x, (blk, c))
                outs.append(nc)
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

    x = norm_apply(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, new_cache


def _decode_qkv(x, p, cfg: ArchConfig, pos):
    b = x.shape[0]
    _, norm_apply = make_norm(cfg.norm)
    h = norm_apply(x, p["ln1"], cfg.norm_eps)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        from .layers import rmsnorm

        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    positions = pos[None]
    q = attn.apply_rope(q, positions, cfg.rope_theta)
    k = attn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _decode_attn_rows(x, p, cfg: ArchConfig, cache_k, cache_v, pos, t):
    """Single-layer decode attention + FFN; cache_k/v: [B, T, Hkv, D]."""
    b = x.shape[0]
    q, k, v = _decode_qkv(x, p, cfg, pos)
    slot = jnp.mod(pos, t)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    o = attn.decode_attention(q, cache_k, cache_v, pos, window=cfg.window)
    x = x + o.reshape(b, 1, -1) @ p["wo"]
    _, norm_apply = make_norm(cfg.norm)
    h2 = norm_apply(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        # single-token groups (s=1) are inherently drop-free: each group
        # carries exactly top_k assignments and capacity ≥ top_k
        y = moelib.moe_ffn(
            h2, p["moe"], n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        y = mlp(h2, p["mlp"], cfg.act)
    return x + y, cache_k, cache_v


def _decode_attn(x, p, cfg: ArchConfig, ck, cv, app: int, pos, t):
    """Shared-block decode for zamba2 (cache rows [n_app, ...])."""
    x, k_new, v_new = _decode_attn_rows(x, p, cfg, ck[app], cv[app], pos, t)
    return x, ck.at[app].set(k_new), cv.at[app].set(v_new)

"""Shared layers: norms, activations, MLPs, embeddings, init helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# initializers (all return cfg-dtype arrays)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (computed in f32, cast back)
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        def init(key, d, dtype):
            return {"w": jnp.ones((d,), dtype)}

        def apply(x, p, eps):
            return rmsnorm(x, p["w"], eps)

    elif kind == "layernorm":
        def init(key, d, dtype):
            return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}

        def apply(x, p, eps):
            return layernorm(x, p["w"], p["b"], eps)

    else:  # pragma: no cover
        raise ValueError(kind)
    return init, apply


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wg": dense_init(ks[0], d, d_ff, dtype),
            "wu": dense_init(ks[1], d, d_ff, dtype),
            "wd": dense_init(ks[2], d_ff, d, dtype),
        }
    return {
        "w1": dense_init(ks[0], d, d_ff, dtype),
        "w2": dense_init(ks[1], d_ff, d, dtype),
    }


def mlp(x: Array, p: dict, act: str) -> Array:
    if act == "swiglu":
        g = jax.nn.silu(x @ p["wg"])
        return (g * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["w1"]) @ p["w2"]

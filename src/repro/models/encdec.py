"""Encoder–decoder transformer (Whisper backbone).

Per the assignment the audio frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings [B, S_enc, D] (S_enc = seq_len / 4, the conv
stem's downsampling factor).  The backbone — bidirectional encoder, causal
decoder with cross-attention, GELU MLPs, LayerNorm, sinusoidal positions —
is implemented fully.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..dist.activation_sharding import constrain
from . import attention as attn
from .layers import dense_init, dtype_of, embed_init, layernorm, make_norm, mlp, init_mlp

Array = jax.Array


def sinusoidal(positions: Array, d: int) -> Array:
    inv = 10000 ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = positions[:, None].astype(jnp.float32) * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_self_block(key, cfg: ArchConfig, dtype, cross: bool):
    ks = jax.random.split(key, 12)
    d = cfg.d_model
    norm_init, _ = make_norm(cfg.norm)
    p = {
        "ln1": norm_init(ks[0], d, dtype),
        "wq": dense_init(ks[1], d, cfg.n_heads * cfg.d_head, dtype),
        "wk": dense_init(ks[2], d, cfg.n_kv_heads * cfg.d_head, dtype),
        "wv": dense_init(ks[3], d, cfg.n_kv_heads * cfg.d_head, dtype),
        "wo": dense_init(ks[4], cfg.n_heads * cfg.d_head, d, dtype),
        "ln_mlp": norm_init(ks[5], d, dtype),
        "mlp": init_mlp(ks[6], d, cfg.d_ff, cfg.act, dtype),
    }
    if cross:
        p.update(
            ln_x=norm_init(ks[7], d, dtype),
            xq=dense_init(ks[8], d, cfg.n_heads * cfg.d_head, dtype),
            xk=dense_init(ks[9], d, cfg.n_kv_heads * cfg.d_head, dtype),
            xv=dense_init(ks[10], d, cfg.n_kv_heads * cfg.d_head, dtype),
            xo=dense_init(ks[11], cfg.n_heads * cfg.d_head, d, dtype),
        )
    return p


def init_params(cfg: ArchConfig, key: Array) -> dict:
    dtype = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    norm_init, _ = make_norm(cfg.norm)
    return {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(
            lambda k: _init_self_block(k, cfg, dtype, cross=False)
        )(jax.random.split(ks[1], cfg.n_enc_layers)),
        "dec_blocks": jax.vmap(
            lambda k: _init_self_block(k, cfg, dtype, cross=True)
        )(jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": norm_init(ks[3], cfg.d_model, dtype),
        "final_norm": norm_init(ks[4], cfg.d_model, dtype),
        "head": dense_init(ks[5], cfg.d_model, cfg.vocab, dtype, scale=0.02),
    }


def _self_attn(x, p, cfg: ArchConfig, causal: bool):
    _, norm_apply = make_norm(cfg.norm)
    x = constrain(x, ("batch", "seq", None))
    b, s, d = x.shape
    h = norm_apply(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (h @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (h @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    o = attn.flash_attention(q, k, v, causal=causal, window=None)
    return x + o.reshape(b, s, -1) @ p["wo"], (k, v)


def _cross_attn(x, enc_kv, p, cfg: ArchConfig):
    _, norm_apply = make_norm(cfg.norm)
    b, s, d = x.shape
    h = norm_apply(x, p["ln_x"], cfg.norm_eps)
    q = (h @ p["xq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k, v = enc_kv
    o = attn.flash_attention(q, k, v, causal=False, window=None)
    return x + o.reshape(b, s, -1) @ p["xo"]


def _mlp_sub(x, p, cfg: ArchConfig):
    _, norm_apply = make_norm(cfg.norm)
    h = norm_apply(x, p["ln_mlp"], cfg.norm_eps)
    return x + mlp(h, p["mlp"], cfg.act)


def encode(cfg: ArchConfig, params: dict, frames: Array) -> Array:
    """frames: [B, S_enc, D] (stub frontend output) → encoder states."""
    b, s, d = frames.shape
    x = frames + sinusoidal(jnp.arange(s), d)[None].astype(frames.dtype)

    def block(x, blk):
        x, _ = _self_attn(x, blk, cfg, causal=False)
        x = _mlp_sub(x, blk, cfg)
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            blk = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = block(x, blk)
    _, norm_apply = make_norm(cfg.norm)
    return norm_apply(x, params["enc_norm"], cfg.norm_eps)


def _enc_kv(enc_out, blk, cfg: ArchConfig):
    b, se, d = enc_out.shape
    k = (enc_out @ blk["xk"]).reshape(b, se, cfg.n_kv_heads, cfg.d_head)
    v = (enc_out @ blk["xv"]).reshape(b, se, cfg.n_kv_heads, cfg.d_head)
    return k, v


def forward(cfg: ArchConfig, params: dict, batch: dict) -> Array:
    """batch: {"frames": [B,S_enc,D], "tokens": [B,S_dec]} → logits."""
    x = forward_hidden(cfg, params, batch)
    return (x @ params["head"]).astype(jnp.float32)


def forward_hidden(cfg: ArchConfig, params: dict, batch: dict) -> Array:
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens] + sinusoidal(
        jnp.arange(s), cfg.d_model
    )[None].astype(params["embed"].dtype)

    def block(x, blk):
        x, _ = _self_attn(x, blk, cfg, causal=True)
        kv = _enc_kv(enc_out, blk, cfg)
        x = _cross_attn(x, kv, blk, cfg)
        x = _mlp_sub(x, blk, cfg)
        return x, None

    block_fn = block
    if cfg.remat:
        block_fn = jax.checkpoint(block)  # full recompute (see transformer.py)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(block_fn, x, params["dec_blocks"])
    else:
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            x, _ = block_fn(x, blk)
    _, norm_apply = make_norm(cfg.norm)
    return norm_apply(x, params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, s_enc: int) -> dict:
    dtype = dtype_of(cfg.dtype)
    l, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "self_k": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
        "self_v": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
        "cross_k": jnp.zeros((l, batch, s_enc, hkv, dh), dtype),
        "cross_v": jnp.zeros((l, batch, s_enc, hkv, dh), dtype),
    }


def prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int):
    """Encode audio + run the decoder prompt; build caches."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens] + sinusoidal(
        jnp.arange(s), cfg.d_model
    )[None].astype(params["embed"].dtype)

    def block(x, blk):
        x, (k, v) = _self_attn(x, blk, cfg, causal=True)
        kv = _enc_kv(enc_out, blk, cfg)
        x = _cross_attn(x, kv, blk, cfg)
        x = _mlp_sub(x, blk, cfg)
        pad = max_len - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, {"self_k": ck, "self_v": cv, "cross_k": kv[0], "cross_v": kv[1]}

    if cfg.scan_layers:
        x, cache = jax.lax.scan(block, x, params["dec_blocks"])
    else:
        outs = []
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            x, c = block(x, blk)
            outs.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    _, norm_apply = make_norm(cfg.norm)
    h = norm_apply(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    return logits, cache, jnp.asarray(s, jnp.int32)


def decode_step(cfg: ArchConfig, params: dict, cache: dict, token: Array,
                pos: Array):
    x = params["embed"][token][:, None] + sinusoidal(
        pos[None], cfg.d_model
    )[None].astype(params["embed"].dtype)
    b = x.shape[0]

    def block(x, blk_and_cache):
        blk, c = blk_and_cache
        _, norm_apply = make_norm(cfg.norm)
        h = norm_apply(x, blk["ln1"], cfg.norm_eps)
        q = (h @ blk["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
        k = (h @ blk["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        v = (h @ blk["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
        ck = jax.lax.dynamic_update_slice_in_dim(c["self_k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(c["self_v"], v, pos, axis=1)
        o = attn.decode_attention(q, ck, cv, pos)
        x = x + o.reshape(b, 1, -1) @ blk["wo"]
        # cross attention against the precomputed encoder KV
        hx = norm_apply(x, blk["ln_x"], cfg.norm_eps)
        qx = (hx @ blk["xq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
        sx = attn.decode_attention(
            qx, c["cross_k"], c["cross_v"], jnp.asarray(c["cross_k"].shape[1] - 1)
        )
        x = x + sx.reshape(b, 1, -1) @ blk["xo"]
        x = _mlp_sub(x, blk, cfg)
        return x, {"self_k": ck, "self_v": cv,
                   "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(block, x, (params["dec_blocks"], cache))
    else:
        outs = []
        for i in range(cfg.n_layers):
            blk = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            c = jax.tree.map(lambda a: a[i], cache)
            x, nc = block(x, (blk, c))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    _, norm_apply = make_norm(cfg.norm)
    h = norm_apply(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0] @ params["head"]).astype(jnp.float32)
    return logits, new_cache

"""Mixture-of-Experts FFN: top-k routing with GShard-style capacity-bounded
einsum dispatch, plus DeepSeekMoE shared experts.

The dispatch formulation keeps expert compute proportional to *activated*
tokens (E · C · FLOPs with E·C = T·k·capacity_factor), so the roofline's
MoE MODEL_FLOPS uses 6·N_active·D.  Tokens beyond an expert's capacity are
dropped (standard GShard behavior); the combine weights renormalize over
surviving assignments.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init

Array = jax.Array


def init_moe(key, d: int, d_expert: int, n_experts: int, n_shared: int, dtype):
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], d, n_experts, jnp.float32),  # f32 routing
        "wg": dense_init(ks[1], d, d_expert, dtype, scale=d**-0.5)[None].repeat(n_experts, 0),
        "wu": dense_init(ks[2], d, d_expert, dtype, scale=d**-0.5)[None].repeat(n_experts, 0),
        "wd": dense_init(ks[3], d_expert, d, dtype)[None].repeat(n_experts, 0),
    }
    # re-randomize per expert (repeat + fold would correlate them)
    for i, name in enumerate(("wg", "wu", "wd")):
        shp = p[name].shape
        p[name] = (
            jax.random.normal(ks[4 + i], shp, jnp.float32) * shp[1] ** -0.5
        ).astype(dtype)
    if n_shared:
        kss = jax.random.split(ks[0], 3)
        p["shared"] = {
            "wg": dense_init(kss[0], d, n_shared * d_expert, dtype),
            "wu": dense_init(kss[1], d, n_shared * d_expert, dtype),
            "wd": dense_init(kss[2], n_shared * d_expert, d, dtype),
        }
    return p


def capacity(tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * factor))
    # ≥ top_k so single-token groups (decode) are always drop-free
    return max(4, top_k, c)


def moe_ffn(
    x: Array,  # [B, S, D]
    p: dict,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 256,
) -> Array:
    """Grouped dispatch (GShard): tokens are routed within fixed-size
    groups so the dispatch/combine tensors are [G, gs, E, C] with
    C ∝ gs·k/E — linear in tokens (one global group would be quadratic).

    Groups are sequence-chunks WITHIN a batch row: the reshape
    [B, S, D] → [B·(S/gs), gs, D] splits the (model-axis-sharded) sequence
    dim at shard boundaries, so the group dim inherits the (batch × seq)
    sharding with no data movement.  Forming groups across batch rows
    instead forces a reshard whose backward XLA resolves by replicating the
    [T, D] gradient (measured: 24 GiB/device on mixtral train)."""
    from ..dist.activation_sharding import constrain

    b, s, d = x.shape
    t = b * s
    gs_sz = min(group_size, s)
    if s % gs_sz:
        gs_sz = s
    n_groups = t // gs_sz
    c = capacity(gs_sz, n_experts, top_k, capacity_factor)

    xt = x.reshape(n_groups, gs_sz, d)
    xt = constrain(xt, ("tokens", None, None))

    logits = jnp.einsum(
        "gtd,de->gte", xt, p["router"].astype(xt.dtype),
        preferred_element_type=jnp.float32,
    )  # [G,gs,E] f32 accumulation without materializing f32 activations
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, top_k)  # [G, gs, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)  # renormalize

    onehot = jax.nn.one_hot(topi, n_experts, dtype=jnp.float32)  # [G,gs,k,E]
    # position of each (token, choice) in its expert's buffer, per group
    flat = onehot.reshape(n_groups, gs_sz * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=1) * flat - 1.0
    pos = pos.reshape(n_groups, gs_sz, top_k, n_experts)
    keep = (pos >= 0) & (pos < c)
    pos = jnp.where(keep, pos, 0.0).astype(jnp.int32)

    pos_onehot = jax.nn.one_hot(pos, c, dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("gtke,gtkec->gtec", onehot, pos_onehot)
    combine = jnp.einsum("gtk,gtke,gtkec->gtec", topv, onehot, pos_onehot)
    dispatch = constrain(dispatch, ("tokens", None, None, None))
    combine = constrain(combine, ("tokens", None, None, None))

    expert_in = jnp.einsum(
        "gtec,gtd->gecd", dispatch.astype(x.dtype), xt,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)  # [G, E, C, D]

    # per-expert SwiGLU
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wg"]))
    u = jnp.einsum("gecd,edf->gecf", expert_in, p["wu"])
    expert_out = jnp.einsum("gecf,efd->gecd", g * u, p["wd"])  # [G, E, C, D]

    out = jnp.einsum(
        "gtec,gecd->gtd", combine.astype(x.dtype), expert_out,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)

    if "shared" in p:
        sh = p["shared"]
        gsh = jax.nn.silu(xt @ sh["wg"]) * (xt @ sh["wu"])
        out = out + gsh @ sh["wd"]
    out = out.reshape(b, s, d)
    # re-pin (batch, seq): the un-merge of the group dim is ambiguous to
    # GSPMD (4096 = B·16 can also read as B-over-256) and the backward
    # resolves the ambiguity by replicating the [B,S,D] f32 cotangent
    # (measured: 24 GiB/device on mixtral)
    return constrain(out, ("batch", "seq", None))

"""Model zoo: the 10 assigned architectures as config-selectable models."""

from .zoo import build_model  # noqa: F401

"""BRASIL → TickPlan compiler.

Enforces the state-effect pattern's read/write legality (paper §2.1/§4.1):

  * query phase (emit value/where expressions): states are READ-ONLY and may
    be read on both SELF and OTHER; effects may not be read; no rand().
  * update phase (update rules / kill): reads SELF states and SELF effects
    only; writes SELF states; rand() allowed.
  * position states with a ``reach`` bound get their updates cropped to
    ±reach per tick (the paper's #range crop), which is what makes the
    distributed runtime's bounded-migration buffers sound.

The output ``TickPlan`` is consumed by core/tick.py (single partition) and
core/distribute.py (shard_map runtime).  Optimizations — effect inversion,
dead-effect elimination, constant folding — live in optimize.py and operate
on the AgentClass/AST level before compilation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.agents import EffectSpec, FieldSpec
from ..core.join import Visibility
from ..core.tick import TickPlan
from . import ast as A
from .fields import AgentClass


class BrasilError(Exception):
    pass


def _check_query_expr(cls: AgentClass, expr: A.Expr, ctx: str):
    for node in A.walk(expr):
        if isinstance(node, A.Rand):
            raise BrasilError(f"{ctx}: rand() is only legal in update rules")
        if isinstance(node, A.Ref):
            if node.kind == "effect":
                raise BrasilError(
                    f"{ctx}: effect fields are write-only during the query phase"
                )
            if node.kind == "state" and node.name not in cls.states:
                raise BrasilError(f"{ctx}: unknown state field {node.name!r}")


def _check_update_expr(cls: AgentClass, expr: A.Expr, ctx: str):
    for node in A.walk(expr):
        if isinstance(node, A.Ref):
            if node.role == A.OTHER:
                raise BrasilError(
                    f"{ctx}: update rules may only read the agent's own fields"
                )
            if node.kind == "state" and node.name not in cls.states:
                raise BrasilError(f"{ctx}: unknown state field {node.name!r}")
            if node.kind == "effect":
                if node.name not in cls.effects:
                    raise BrasilError(f"{ctx}: unknown effect field {node.name!r}")
                decl = cls.effects[node.name]
                if node.component and node.component != "key":
                    if node.component not in [p[0] for p in decl.payload]:
                        raise BrasilError(
                            f"{ctx}: effect {node.name!r} has no payload "
                            f"{node.component!r}"
                        )


def _renumber_rands(cls: AgentClass) -> None:
    """Assign deterministic Rand tags in declaration order so structurally
    identical programs (e.g. a script and its effect-inverted twin) draw
    identical random streams."""
    seen: set[int] = set()
    counter = 0
    exprs = list(cls.updates.values())
    if cls.alive_rule is not None:
        exprs.append(cls.alive_rule)
    for expr in exprs:
        for node in A.walk(expr):
            if isinstance(node, A.Rand) and id(node) not in seen:
                seen.add(id(node))
                object.__setattr__(node, "tag", counter)
                counter += 1


def validate(cls: AgentClass) -> None:
    _renumber_rands(cls)
    for e in cls.emits:
        ctx = f"emit → {e.effect}"
        vals = e.value.values() if isinstance(e.value, dict) else [e.value]
        for v in vals:
            _check_query_expr(cls, v, ctx)
        if e.where is not None:
            _check_query_expr(cls, e.where, ctx)
    for name, expr in cls.updates.items():
        _check_update_expr(cls, expr, f"update {name}")
    if cls.alive_rule is not None:
        _check_update_expr(cls, cls.alive_rule, "kill")
    for p in cls.position:
        if p not in cls.states:
            raise BrasilError(f"position field {p!r} is not a declared state")


def field_specs(cls: AgentClass) -> list[FieldSpec]:
    return [
        FieldSpec(s.name, shape=tuple(s.shape), dtype=s.dtype)
        for s in cls.states.values()
    ]


def effect_specs(cls: AgentClass) -> list[EffectSpec]:
    return [
        EffectSpec(
            e.name, comb=e.comb, shape=tuple(e.shape), dtype=e.dtype, payload=e.payload
        )
        for e in cls.effects.values()
    ]


def reach_bounds(cls: AgentClass) -> tuple[float, float]:
    rx = cls.states[cls.position[0]].reach
    ry = cls.states[cls.position[1]].reach
    return (
        float(rx) if rx is not None else float("inf"),
        float(ry) if ry is not None else float("inf"),
    )


def compile_agent(cls: AgentClass) -> TickPlan:
    """Lower an AgentClass to an executable TickPlan."""
    validate(cls)
    emits = list(cls.emits)
    updates = dict(cls.updates)
    alive_rule = cls.alive_rule
    has_nonlocal = any(e.target == "other" for e in emits)

    def pair_fn(self_env, other_env, params):
        env = A.EvalEnv(self_env, other_env, effects=None, params=params)
        out = []
        for e in emits:
            if isinstance(e.value, dict):
                val = {k: A.evaluate(v, env) for k, v in e.value.items()}
                # broadcast every component to the pair shape [N, K]
                shape = jnp.broadcast_shapes(*[v.shape for v in val.values()])
                val = {k: jnp.broadcast_to(v, shape) for k, v in val.items()}
            else:
                val = A.evaluate(e.value, env)
            cond = None if e.where is None else A.evaluate(e.where, env)
            out.append((e.target, e.effect, val, cond))
        return out

    position = cls.position
    reaches = {
        s.name: s.reach for s in cls.states.values() if s.reach is not None
    }
    wraps = {s.name: s.wrap for s in cls.states.values() if s.wrap is not None}

    def update_fn(fields, effects, params, rng, t, oid=None):
        env = A.EvalEnv(fields, None, effects=effects, params=params, rng=rng, oid=oid)
        new_fields = dict(fields)
        for name, expr in updates.items():
            val = A.evaluate(expr, env)
            val = jnp.broadcast_to(val, fields[name].shape).astype(fields[name].dtype)
            if name in reaches:  # #range crop
                r = reaches[name]
                delta = val - fields[name]
                if name in wraps:  # shortest displacement on the circle
                    period = wraps[name]
                    delta = delta - period * jnp.round(delta / period)
                val = fields[name] + jnp.clip(delta, -r, r)
            if name in wraps:
                val = jnp.mod(val, wraps[name])
            new_fields[name] = val
        n = next(iter(fields.values())).shape[0]
        alive = jnp.ones((n,), bool)
        if alive_rule is not None:
            alive = ~jnp.broadcast_to(A.evaluate(alive_rule, env), (n,))
        return new_fields, alive

    periods = tuple(
        cls.states[p].wrap if cls.states[p].wrap is not None else None
        for p in cls.position
    )
    vis = Visibility(
        pos_fields=position, bounds=cls.visibility, radius=cls.radius, periods=periods
    )
    return TickPlan(
        effect_specs=effect_specs(cls),
        pair_fn=pair_fn,
        update_fn=update_fn,
        visibility=vis,
        reach=reach_bounds(cls),
        has_nonlocal=has_nonlocal,
    )

"""BRASIL — the Big Red Agent SImulation Language, as an embedded JAX DSL."""

from .ast import (  # noqa: F401
    Eff,
    Other,
    Param,
    Self,
    abs_,
    atan2,
    clip,
    cos,
    exp,
    floor,
    log,
    maximum,
    minimum,
    rand_normal,
    rand_uniform,
    sign,
    sin,
    sqrt,
    to_float,
    to_int,
    where,
)
from .compiler import BrasilError, compile_agent, effect_specs, field_specs  # noqa: F401
from .fields import AgentClass  # noqa: F401
from .optimize import (  # noqa: F401
    eliminate_dead_effects,
    fold_program_constants,
    invert_effects,
    optimize,
    widen_visibility,
)

"""BRASIL expression AST.

BRASIL (paper §4) is an agent-centric language whose restrictions — state /
effect field tagging, foreach-only iteration, combinator-aggregated effect
assignment — make every program compilable to a data-flow plan.  The paper
compiles to the monad algebra; here the embedded-DSL equivalent is a small
expression AST that the compiler lowers onto vectorized JAX, which plays the
same role (§4.2's algebraic rewrites operate on this AST).

Expressions are built by operator overloading::

    gap = Other("x") - Self("x")
    F.emit("self", "lead", key=gap, where=(gap > 0) & (Other("lane") == Self("lane")))
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array

SELF = "self"
OTHER = "other"

_rand_counter = itertools.count()


class Expr:
    """Base expression node (operator overloading builds the tree)."""

    # arithmetic ------------------------------------------------------------
    def __add__(self, o): return BinOp("add", self, wrap(o))
    def __radd__(self, o): return BinOp("add", wrap(o), self)
    def __sub__(self, o): return BinOp("sub", self, wrap(o))
    def __rsub__(self, o): return BinOp("sub", wrap(o), self)
    def __mul__(self, o): return BinOp("mul", self, wrap(o))
    def __rmul__(self, o): return BinOp("mul", wrap(o), self)
    def __truediv__(self, o): return BinOp("div", self, wrap(o))
    def __rtruediv__(self, o): return BinOp("div", wrap(o), self)
    def __mod__(self, o): return BinOp("mod", self, wrap(o))
    def __pow__(self, o): return BinOp("pow", self, wrap(o))
    def __neg__(self): return BinOp("mul", Const(-1.0), self)

    # comparisons -----------------------------------------------------------
    def __lt__(self, o): return Cmp("lt", self, wrap(o))
    def __le__(self, o): return Cmp("le", self, wrap(o))
    def __gt__(self, o): return Cmp("gt", self, wrap(o))
    def __ge__(self, o): return Cmp("ge", self, wrap(o))
    def eq(self, o): return Cmp("eq", self, wrap(o))
    def ne(self, o): return Cmp("ne", self, wrap(o))

    # boolean ---------------------------------------------------------------
    def __and__(self, o): return BinOp("and", self, wrap(o))
    def __or__(self, o): return BinOp("or", self, wrap(o))
    def __invert__(self): return Call("not", (self,))

    def __hash__(self):  # identity hash; trees are not deduplicated
        return id(self)


def wrap(v: Any) -> Expr:
    if isinstance(v, Expr):
        return v
    if isinstance(v, (int, float, bool)):
        return Const(v)
    raise TypeError(f"cannot use {type(v).__name__} in a BRASIL expression")


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    value: Any


@dataclasses.dataclass(frozen=True, eq=False)
class Ref(Expr):
    """Reference to a field of the active agent (SELF) or the foreach
    iteration variable (OTHER)."""

    role: str  # SELF | OTHER
    kind: str  # "state" | "effect" | "param"
    name: str
    component: str | None = None  # payload component for min_by/max_by


@dataclasses.dataclass(frozen=True, eq=False)
class Rand(Expr):
    """Per-agent random draw (update phase only, like the paper's rand())."""

    kind: str = "uniform"  # uniform [0,1) | normal
    tag: int = dataclasses.field(default_factory=lambda: next(_rand_counter))


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class Cmp(Expr):
    op: str
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class Where(Expr):
    cond: Expr
    a: Expr
    b: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class Call(Expr):
    fn: str
    args: tuple


# ---------------------------------------------------------------------------
# public constructors
# ---------------------------------------------------------------------------

def Self(name: str) -> Ref:
    return Ref(SELF, "state", name)


def Other(name: str) -> Ref:
    return Ref(OTHER, "state", name)


def Eff(name: str, component: str | None = None) -> Ref:
    return Ref(SELF, "effect", name, component)


def Param(name: str) -> Ref:
    return Ref(SELF, "param", name)


def rand_uniform() -> Rand:
    return Rand("uniform")


def rand_normal() -> Rand:
    return Rand("normal")


def where(cond, a, b) -> Where:
    return Where(wrap(cond), wrap(a), wrap(b))


def _call1(fn):
    return lambda a: Call(fn, (wrap(a),))


abs_ = _call1("abs")
exp = _call1("exp")
log = _call1("log")
sqrt = _call1("sqrt")
floor = _call1("floor")
sign = _call1("sign")
sin = _call1("sin")
cos = _call1("cos")
to_float = _call1("float")
to_int = _call1("int")


def minimum(a, b) -> Expr:
    return Call("minimum", (wrap(a), wrap(b)))


def maximum(a, b) -> Expr:
    return Call("maximum", (wrap(a), wrap(b)))


def clip(a, lo, hi) -> Expr:
    return Call("clip", (wrap(a), wrap(lo), wrap(hi)))


def atan2(a, b) -> Expr:
    return Call("atan2", (wrap(a), wrap(b)))


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

_BINOPS = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "mod": lambda a, b: a % b,
    "pow": lambda a, b: a**b,
    "and": jnp.logical_and,
    "or": jnp.logical_or,
}

_CMPS = {
    "lt": lambda a, b: a < b,
    "le": lambda a, b: a <= b,
    "gt": lambda a, b: a > b,
    "ge": lambda a, b: a >= b,
    "eq": lambda a, b: a == b,
    "ne": lambda a, b: a != b,
}

_CALLS = {
    "abs": jnp.abs,
    "exp": jnp.exp,
    "log": jnp.log,
    "sqrt": jnp.sqrt,
    "floor": jnp.floor,
    "sign": jnp.sign,
    "sin": jnp.sin,
    "cos": jnp.cos,
    "not": jnp.logical_not,
    "minimum": jnp.minimum,
    "maximum": jnp.maximum,
    "clip": jnp.clip,
    "atan2": jnp.arctan2,
    "float": lambda a: a.astype(jnp.float32),
    "int": lambda a: a.astype(jnp.int32),
}


class EvalEnv:
    """Binding of AST references to arrays for one evaluation context."""

    def __init__(
        self,
        self_state: dict[str, Array],
        other_state: dict[str, Array] | None,
        effects: dict[str, Any] | None,
        params: dict[str, Any],
        rng: Array | None = None,
        oid: Array | None = None,
    ):
        self.self_state = self_state
        self.other_state = other_state
        self.effects = effects
        self.params = params
        self.rng = rng
        self.oid = oid

    def ref(self, node: Ref) -> Array:
        if node.kind == "param":
            return jnp.asarray(self.params[node.name])
        if node.kind == "state":
            src = self.self_state if node.role == SELF else self.other_state
            if src is None:
                raise KeyError(f"{node.role}.{node.name} not available here")
            return src[node.name]
        if node.kind == "effect":
            if self.effects is None:
                raise KeyError(f"effect {node.name} not available here")
            v = self.effects[node.name]
            if isinstance(v, dict):
                return v[node.component or "key"]
            return v
        raise KeyError(node.kind)

    def rand(self, node: Rand) -> Array:
        if self.rng is None:
            raise RuntimeError("rand() not available in this phase")
        key = jax.random.fold_in(self.rng, node.tag)
        if self.oid is not None:
            # Per-agent streams keyed by oid: randomness is identical no
            # matter how agents are partitioned across devices — single-node
            # and distributed trajectories agree bitwise.
            keys = jax.vmap(lambda o: jax.random.fold_in(key, o))(self.oid)
            draw = jax.random.uniform if node.kind == "uniform" else jax.random.normal
            return jax.vmap(lambda k: draw(k, ()))(keys)
        shape = next(iter(self.self_state.values())).shape[:1]
        if node.kind == "uniform":
            return jax.random.uniform(key, shape)
        return jax.random.normal(key, shape)


def evaluate(expr: Expr, env: EvalEnv) -> Array:
    if isinstance(expr, Const):
        return jnp.asarray(expr.value)
    if isinstance(expr, Ref):
        return env.ref(expr)
    if isinstance(expr, Rand):
        return env.rand(expr)
    if isinstance(expr, BinOp):
        return _BINOPS[expr.op](evaluate(expr.a, env), evaluate(expr.b, env))
    if isinstance(expr, Cmp):
        return _CMPS[expr.op](evaluate(expr.a, env), evaluate(expr.b, env))
    if isinstance(expr, Where):
        return jnp.where(
            evaluate(expr.cond, env), evaluate(expr.a, env), evaluate(expr.b, env)
        )
    if isinstance(expr, Call):
        return _CALLS[expr.fn](*[evaluate(a, env) for a in expr.args])
    raise TypeError(f"not a BRASIL expression: {expr!r}")


def walk(expr: Expr):
    """Yield every node in the tree."""
    yield expr
    if isinstance(expr, (BinOp, Cmp)):
        yield from walk(expr.a)
        yield from walk(expr.b)
    elif isinstance(expr, Where):
        yield from walk(expr.cond)
        yield from walk(expr.a)
        yield from walk(expr.b)
    elif isinstance(expr, Call):
        for a in expr.args:
            yield from walk(a)


def swap_roles(expr: Expr) -> Expr:
    """SELF↔OTHER — the core of effect inversion (paper Thm 2/3)."""
    if isinstance(expr, Ref):
        if expr.kind == "state":
            role = OTHER if expr.role == SELF else SELF
            return Ref(role, expr.kind, expr.name, expr.component)
        return expr
    if isinstance(expr, BinOp):
        return BinOp(expr.op, swap_roles(expr.a), swap_roles(expr.b))
    if isinstance(expr, Cmp):
        return Cmp(expr.op, swap_roles(expr.a), swap_roles(expr.b))
    if isinstance(expr, Where):
        return Where(swap_roles(expr.cond), swap_roles(expr.a), swap_roles(expr.b))
    if isinstance(expr, Call):
        return Call(expr.fn, tuple(swap_roles(a) for a in expr.args))
    return expr


def fold_constants(expr: Expr) -> Expr:
    """Bottom-up constant folding (one of §4.2's algebraic rewrites)."""
    if isinstance(expr, BinOp):
        a, b = fold_constants(expr.a), fold_constants(expr.b)
        if isinstance(a, Const) and isinstance(b, Const):
            import numpy as np

            val = _BINOPS[expr.op](np.asarray(a.value), np.asarray(b.value))
            return Const(val.item() if hasattr(val, "item") else val)
        return BinOp(expr.op, a, b)
    if isinstance(expr, Cmp):
        return Cmp(expr.op, fold_constants(expr.a), fold_constants(expr.b))
    if isinstance(expr, Where):
        c = fold_constants(expr.cond)
        a, b = fold_constants(expr.a), fold_constants(expr.b)
        if isinstance(c, Const):
            return a if c.value else b
        return Where(c, a, b)
    if isinstance(expr, Call):
        return Call(expr.fn, tuple(fold_constants(a) for a in expr.args))
    return expr

"""AgentClass — the BRASIL class declaration (paper §4.1, Fig. 2).

The embedded-DSL equivalent of a BRASIL class file: state fields with
update rules and ``#range`` constraints, effect fields with combinators,
parameters, and the query phase's foreach body expressed as effect
emissions.  The compiler (compiler.py) enforces the state-effect pattern's
read/write restrictions when lowering.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from .ast import Expr, wrap


@dataclasses.dataclass
class StateDecl:
    name: str
    dtype: Any = jnp.float32
    shape: tuple = ()
    reach: float | None = None  # per-tick movement bound (#range), position axes
    wrap: float | None = None   # periodic domain: value ← value mod wrap


@dataclasses.dataclass
class EffectDecl:
    name: str
    comb: str = "sum"
    dtype: Any = jnp.float32
    shape: tuple = ()
    payload: tuple = ()  # (name, shape, dtype) triples for min_by/max_by


@dataclasses.dataclass
class Emit:
    """One effect assignment inside the foreach-loop (``<-`` in BRASIL)."""

    target: str  # "self" (local) | "other" (non-local)
    effect: str
    value: Any  # Expr, or dict[str, Expr] for min_by/max_by ({"key", payloads})
    where: Expr | None = None


class AgentClass:
    """Declarative agent class; see sims/ for complete examples."""

    def __init__(
        self,
        name: str,
        position: tuple[str, str],
        visibility: tuple[float, float],
        radius: float | None = None,
    ):
        self.name = name
        self.position = tuple(position)
        self.visibility = tuple(float(v) for v in visibility)
        self.radius = radius
        self.states: dict[str, StateDecl] = {}
        self.effects: dict[str, EffectDecl] = {}
        self.params: dict[str, Any] = {}
        self.emits: list[Emit] = []
        self.updates: dict[str, Expr] = {}
        self.alive_rule: Expr | None = None

    # ---- declarations -----------------------------------------------------
    def state(
        self,
        name: str,
        dtype=jnp.float32,
        reach: float | None = None,
        wrap: float | None = None,
    ):
        if name in self.states:
            raise ValueError(f"duplicate state field {name!r}")
        self.states[name] = StateDecl(name, dtype=dtype, reach=reach, wrap=wrap)
        return self

    def effect(self, name: str, comb: str = "sum", dtype=jnp.float32, payload=()):
        if name in self.effects:
            raise ValueError(f"duplicate effect field {name!r}")
        payload = tuple(
            (p, (), jnp.float32) if isinstance(p, str) else tuple(p) for p in payload
        )
        self.effects[name] = EffectDecl(name, comb=comb, dtype=dtype, payload=payload)
        return self

    def param(self, name: str, default: Any):
        self.params[name] = default
        return self

    # ---- query phase (the foreach body) ------------------------------------
    def emit(self, target: str, effect: str, value, where=None):
        if target not in ("self", "other"):
            raise ValueError("emit target must be 'self' or 'other'")
        if effect not in self.effects:
            raise ValueError(f"unknown effect field {effect!r}")
        decl = self.effects[effect]
        if decl.comb in ("min_by", "max_by"):
            if not isinstance(value, dict) or "key" not in value:
                raise ValueError(
                    f"{decl.comb} emission needs a dict with 'key' (+payloads)"
                )
            value = {k: wrap(v) for k, v in value.items()}
        else:
            value = wrap(value)
        self.emits.append(
            Emit(target, effect, value, None if where is None else wrap(where))
        )
        return self

    # ---- update phase -------------------------------------------------------
    def update(self, state: str, value):
        if state not in self.states:
            raise ValueError(f"unknown state field {state!r}")
        if state in self.updates:
            raise ValueError(f"duplicate update rule for {state!r}")
        self.updates[state] = wrap(value)
        return self

    def kill(self, cond):
        """alive ← alive ∧ ¬cond, evaluated in the update phase."""
        self.alive_rule = wrap(cond)
        return self

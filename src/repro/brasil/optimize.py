"""Algebraic optimizations on BRASIL programs (paper §4.2).

* ``invert_effects`` — the paper's headline rewrite (Theorems 2/3): rewrite
  non-local (scatter, target="other") effect assignments into local (gather,
  target="self") ones by swapping SELF↔OTHER in the emission's value and
  guard expressions.  In the embedded DSL every emission is pairwise and
  guarded by the class's visibility predicate; our predicates (per-axis
  boxes ∩ optional L2 ball, evaluated on position *differences*) are
  symmetric, so inversion is exact at the same bound — this is the Thm 2
  situation specialized to pairwise programs.  Thm 3's doubled bound covers
  the proxy pattern (a reads b, writes c) which the pairwise foreach API
  cannot express; ``widen_visibility`` is provided for completeness and used
  by the distributed runtime's temporal-blocking mode.

* ``eliminate_dead_effects`` — drop effect fields (and their emissions) that
  no update rule or kill condition reads; the data-flow analogue of
  dead-code elimination mentioned in App. B.1.

* ``fold_program_constants`` — constant folding over every expression.
"""

from __future__ import annotations

import copy

from . import ast as A
from .fields import AgentClass, Emit


def invert_effects(cls: AgentClass) -> AgentClass:
    """Return a copy with every non-local emission made local (Thm 2)."""
    out = copy.deepcopy(cls)
    new_emits = []
    for e in out.emits:
        if e.target == "other":
            value = (
                {k: A.swap_roles(v) for k, v in e.value.items()}
                if isinstance(e.value, dict)
                else A.swap_roles(e.value)
            )
            where = None if e.where is None else A.swap_roles(e.where)
            new_emits.append(Emit("self", e.effect, value, where))
        else:
            new_emits.append(e)
    out.emits = new_emits
    return out


def widen_visibility(cls: AgentClass, factor: float = 2.0) -> AgentClass:
    """Thm 3: a wider bound lets a local-only script observe everything a
    proxy could relay; also used for temporal blocking halos."""
    out = copy.deepcopy(cls)
    out.visibility = tuple(v * factor for v in out.visibility)
    if out.radius is not None:
        out.radius = out.radius * factor
    return out


def _read_effects(cls: AgentClass) -> set[str]:
    read: set[str] = set()
    exprs = list(cls.updates.values())
    if cls.alive_rule is not None:
        exprs.append(cls.alive_rule)
    for expr in exprs:
        for node in A.walk(expr):
            if isinstance(node, A.Ref) and node.kind == "effect":
                read.add(node.name)
    return read


def eliminate_dead_effects(cls: AgentClass) -> AgentClass:
    read = _read_effects(cls)
    out = copy.deepcopy(cls)
    out.effects = {k: v for k, v in out.effects.items() if k in read}
    out.emits = [e for e in out.emits if e.effect in read]
    return out


def fold_program_constants(cls: AgentClass) -> AgentClass:
    out = copy.deepcopy(cls)
    for e in out.emits:
        if isinstance(e.value, dict):
            e.value = {k: A.fold_constants(v) for k, v in e.value.items()}
        else:
            e.value = A.fold_constants(e.value)
        if e.where is not None:
            e.where = A.fold_constants(e.where)
    out.updates = {k: A.fold_constants(v) for k, v in out.updates.items()}
    if out.alive_rule is not None:
        out.alive_rule = A.fold_constants(out.alive_rule)
    return out


def optimize(cls: AgentClass, invert: bool = True) -> AgentClass:
    """The default pipeline: fold → DCE → (optionally) invert."""
    out = fold_program_constants(cls)
    out = eliminate_dead_effects(out)
    if invert:
        out = invert_effects(out)
    return out

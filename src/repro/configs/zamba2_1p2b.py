"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared (tied-weight) attention
blocks [arXiv:2411.15242; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, shared_attn_period=6,
    train_microbatches=2,
))

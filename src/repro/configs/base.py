"""Architecture + shape registry for the assigned evaluation pool.

Every architecture is a selectable config (``--arch <id>``); every
(arch × shape) cell is exercised by the multi-pod dry-run
(launch/dryrun.py) and recorded in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int | None = None  # sliding-window attention
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_period: int = 0  # zamba2: shared attn block every k layers
    # RWKV6
    rwkv_head_size: int = 64
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_frames_divisor: int = 4  # stub conv frontend downsampling factor
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # gradient-accumulation microbatches for train_4k (memory, not math):
    # activation-linked buffers scale with the per-microbatch batch
    train_microbatches: int = 1

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        d = self.d_model
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            h = d // self.rwkv_head_size
            # time-mix: r,k,v,g,o (d×d) + decay lora (d×64×2) + ffn
            per_layer = 5 * d * d + 2 * d * 64 + d * 64 * 2 + 2 * d * self.d_ff
            per_layer += 4 * d  # norms, mixes
        else:
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head
            attn += self.n_heads * self.d_head * d
            if self.is_moe:
                ffn = self.n_experts * 3 * d * self.d_expert
                ffn += self.n_shared_experts * 3 * d * self.d_expert
                ffn += d * self.n_experts  # router
            elif self.act == "swiglu":
                ffn = 3 * d * self.d_ff
            else:
                ffn = 2 * d * self.d_ff
            if self.family == "hybrid":
                din = self.ssm_expand * d
                mamba = d * (2 * din + 2 * self.ssm_state) + din * d
                per_layer = mamba + 2 * d
                shared = attn + 3 * d * self.d_ff
                n_shared_blocks = 1  # tied weights
                return (
                    emb + head + self.n_layers * per_layer + shared * n_shared_blocks
                )
            per_layer = attn + ffn + 2 * d
        total = emb + head + self.n_layers * per_layer
        if self.family == "encdec":
            enc_layer = (
                d * self.n_heads * self.d_head * 2
                + 2 * d * self.n_kv_heads * self.d_head
                + 2 * d * self.d_ff
                + 2 * d
            )
            cross = d * self.n_heads * self.d_head * 2 + 2 * d * self.n_kv_heads * self.d_head
            total += self.n_enc_layers * enc_layer + self.n_layers * cross
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE routing)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        routed_all = self.n_experts * 3 * d * self.d_expert
        routed_active = self.top_k * 3 * d * self.d_expert
        return self.param_count() - self.n_layers * (routed_all - routed_active)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def supports(arch: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (SWA / SSM / hybrid).

    Returns (supported, reason-if-not).
    """
    if shape.name == "long_500k":
        sub_quadratic = (
            arch.family in ("ssm", "hybrid") or arch.window is not None
        )
        if not sub_quadratic:
            return False, (
                "pure full attention — 512k decode context is quadratic; "
                "skipped per assignment (see DESIGN.md §Arch-applicability)"
            )
    return True, ""


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")


def all_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (  # noqa: F401
        chameleon_34b,
        deepseek_moe_16b,
        granite_8b,
        h2o_danube3_4b,
        mixtral_8x22b,
        qwen15_110b,
        qwen2_7b,
        rwkv6_7b,
        whisper_base,
        zamba2_1p2b,
    )


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A small same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads else 0,
        d_head=16,
        d_ff=128,
        vocab=256,
        scan_layers=False,
        remat=False,
        dtype="float32",
    )
    if cfg.is_moe:
        # capacity_factor = E makes the reduced config drop-free, so the
        # serve path can be checked exactly against the full forward
        small.update(n_experts=4, top_k=min(2, cfg.top_k), d_expert=32,
                     n_shared_experts=min(1, cfg.n_shared_experts),
                     capacity_factor=4.0)
    if cfg.family in ("hybrid", "ssm"):
        small.update(ssm_state=8, ssm_head_dim=16, rwkv_head_size=16)
    if cfg.family == "hybrid":
        small.update(shared_attn_period=2, n_kv_heads=4)
    if cfg.family == "encdec":
        small.update(n_enc_layers=2)
    if cfg.window is not None:
        small.update(window=32)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)

"""whisper-base [audio]: enc-dec transformer backbone; the conv frontend is
a STUB (input_specs provides precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, vocab=51865,
    n_enc_layers=6, act="gelu", norm="layernorm",
))

"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=14336, vocab=65536, rwkv_head_size=64,
))

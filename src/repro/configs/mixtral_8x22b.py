"""mixtral-8x22b [moe]: 8 experts top-2 with SWA [arXiv:2401.04088; hf]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=32768, window=4096,
    n_experts=8, n_shared_experts=0, top_k=2, d_expert=16384,
    train_microbatches=8,
))

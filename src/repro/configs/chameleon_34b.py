"""chameleon-34b [vlm]: early-fusion backbone over unified text+VQ-image
token vocabulary; tokenizer frontend is a STUB.  Uses qk-norm
[arXiv:2405.09818; unverified]."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536, qk_norm=True,
    train_microbatches=4,
))

from .base import ArchConfig, ShapeSpec, SHAPES, all_archs, get_arch, reduced, supports  # noqa: F401

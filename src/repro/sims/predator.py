"""Predator simulation with non-local effect assignments (paper §5.1/App. C).

"A fish can 'spawn' new fish and 'bite' other fish, possibly killing them,
so density naturally approaches an equilibrium" — inspired by artificial-
society simulations.  The *bite* is the paper's canonical non-local effect:
a predator assigns a ``hurt`` effect to prey in its bite radius.

Two scripts, identical semantics (paper: "we program biting behavior either
as a non-local effect assignment ... or as a local one ... in otherwise
identical BRASIL scripts"):

  * scatter form (``inverted=False``): pred → ``Other.hurt <- damage``
    ⇒ BRACE needs the two-pass map-reduce-reduce (Fig. 5's 2-reduce bars);
  * gather form (``inverted=True``): produced *automatically* by the
    compiler's effect inversion (Thm 2) — our compiler implements what the
    paper hand-wrote — ⇒ single reduce pass.

Deaths are in-tick (alive mask); spawning is a host-side epoch hook into
free capacity slots (master.py), keeping shapes static.
"""

from __future__ import annotations

import numpy as np

from ..brasil import (
    AgentClass,
    Eff,
    Other,
    Param,
    Self,
    invert_effects,
    rand_normal,
    sqrt,
    where,
)
from ..core.agents import AgentState
from ..core.engine import Simulation


def make_predator_class(
    rho: float = 1.0,
    bite_r: float = 0.25,
    damage: float = 30.0,
    regen: float = 3.0,
    starve: float = 1.5,
    feed: float = 12.0,
    speed: float = 0.06,
    noise: float = 0.3,
    inverted: bool = False,
) -> AgentClass:
    P = AgentClass("Agent", position=("x", "y"), visibility=(rho, rho), radius=rho)
    P.state("x", reach=speed).state("y", reach=speed)
    P.state("kind")      # 0 = prey, 1 = predator
    P.state("health")
    P.effect("hurt", "sum")      # the non-local effect
    P.effect("fed", "sum")       # predator's meals (local gather)
    P.effect("fleex", "sum").effect("fleey", "sum")   # prey threat vector
    P.effect("chasex", "min_by", payload=["dx", "dy"])  # nearest prey
    for name, val in dict(
        bite_r=bite_r, damage=damage, regen=regen, starve=starve,
        feed=feed, speed=speed, noise=noise,
    ).items():
        P.param(name, val)

    eps = 1e-6
    dx = Other("x") - Self("x")
    dy = Other("y") - Self("y")
    dist2 = dx * dx + dy * dy
    dist = sqrt(dist2) + eps
    i_pred = Self("kind") > 0.5
    o_pred = Other("kind") > 0.5
    in_bite = dist2 < Param("bite_r") * Param("bite_r")

    # THE non-local assignment: predator hurts prey (scatter form)
    P.emit("other", "hurt", Param("damage"), where=i_pred & ~o_pred & in_bite)
    # predator's feeding is the symmetric local gather (kept local so the
    # scatter/gather scripts differ ONLY in the hurt assignment, like Fig. 5)
    P.emit("self", "fed", Param("feed"), where=i_pred & ~o_pred & in_bite)
    # prey flees predators; predators chase nearest prey
    P.emit("self", "fleex", -dx / dist, where=~i_pred & o_pred)
    P.emit("self", "fleey", -dy / dist, where=~i_pred & o_pred)
    P.emit(
        "self", "chasex", {"key": dist2, "dx": dx, "dy": dy},
        where=i_pred & ~o_pred,
    )

    # ---- update -------------------------------------------------------------
    is_pred = Self("kind") > 0.5
    # movement
    cx = Eff("chasex", "dx")
    cy = Eff("chasex", "dy")
    has_prey = Eff("chasex") < 1.0e30
    pnorm = sqrt(cx * cx + cy * cy) + eps
    mx_pred = where(has_prey, cx / pnorm, 0.0) + Param("noise") * rand_normal()
    my_pred = where(has_prey, cy / pnorm, 0.0) + Param("noise") * rand_normal()
    fx = Eff("fleex")
    fy = Eff("fleey")
    fnorm = sqrt(fx * fx + fy * fy) + eps
    threatened = fnorm > 0.1
    mx_prey = where(threatened, fx / fnorm, 0.0) + Param("noise") * rand_normal()
    my_prey = where(threatened, fy / fnorm, 0.0) + Param("noise") * rand_normal()
    mx = where(is_pred, mx_pred, mx_prey)
    my = where(is_pred, my_pred, my_prey)
    mnorm = sqrt(mx * mx + my * my) + eps
    P.update("x", Self("x") + Param("speed") * mx / mnorm)
    P.update("y", Self("y") + Param("speed") * my / mnorm)
    # health: prey regenerate and take bites; predators starve and feed
    from ..brasil import minimum

    h_prey = Self("health") + Param("regen") - Eff("hurt")
    h_pred = Self("health") - Param("starve") + Eff("fed")
    h_new = where(is_pred, h_pred, h_prey)
    P.update("health", minimum(h_new, 100.0))
    P.kill(h_new <= 0.0)

    if inverted:
        return invert_effects(P)
    return P


def make_predator_sim(
    world: tuple[float, float] = (20.0, 20.0), inverted: bool = False, **kw
) -> Simulation:
    P = make_predator_class(inverted=inverted, **kw)
    return Simulation.build(P, world_lo=(0.0, 0.0), world_hi=world)


def init_population(
    sim: Simulation,
    n_prey: int,
    n_pred: int,
    capacity: int,
    seed: int = 0,
):
    rs = np.random.RandomState(seed)
    n = n_prey + n_pred
    lo, hi = sim.world_lo, sim.world_hi
    x = rs.uniform(lo[0], hi[0], n).astype(np.float32)
    y = rs.uniform(lo[1], hi[1], n).astype(np.float32)
    kind = np.concatenate(
        [np.zeros(n_prey, np.float32), np.ones(n_pred, np.float32)]
    )
    health = np.full(n, 80.0, np.float32)
    return sim.init_population(
        capacity, oid=np.arange(n), x=x, y=y, kind=kind, health=health
    )


def make_spawn_hook(
    spawn_threshold: float = 95.0,
    spawn_health: float = 50.0,
    jitter: float = 0.2,
    max_spawn_per_epoch: int = 64,
    seed: int = 0,
):
    """Host-side epoch hook: healthy prey split into free capacity slots."""
    rs = np.random.RandomState(seed)

    def hook(state: AgentState, tick: int) -> AgentState:
        import jax.numpy as jnp

        alive = np.asarray(state.alive).copy()
        health = np.asarray(state.fields["health"]).copy()
        kind = np.asarray(state.fields["kind"]).copy()
        x = np.asarray(state.fields["x"]).copy()
        y = np.asarray(state.fields["y"]).copy()
        oid = np.asarray(state.oid).copy()

        parents = np.nonzero(alive & (kind < 0.5) & (health >= spawn_threshold))[0]
        free = np.nonzero(~alive)[0]
        k = min(len(parents), len(free), max_spawn_per_epoch)
        if k > 0:
            ps, fs = parents[:k], free[:k]
            alive[fs] = True
            kind[fs] = 0.0
            health[fs] = spawn_health
            health[ps] = health[ps] - spawn_health * 0.5
            x[fs] = x[ps] + rs.uniform(-jitter, jitter, k).astype(np.float32)
            y[fs] = y[ps] + rs.uniform(-jitter, jitter, k).astype(np.float32)
            oid[fs] = oid.max() + 1 + np.arange(k)
        fields = dict(state.fields)
        fields.update(
            x=jnp.asarray(x), y=jnp.asarray(y),
            kind=jnp.asarray(kind), health=jnp.asarray(health),
        )
        return AgentState(alive=jnp.asarray(alive), oid=jnp.asarray(oid), fields=fields)

    return hook

"""The paper's simulation workloads (§5.1, App. C): traffic (MITSIM lane
changing + car following), fish school (Couzin information transfer), and
the predator simulation with non-local effect assignments."""

from .fish import make_fish_class, make_fish_sim  # noqa: F401
from .predator import make_predator_class, make_predator_sim  # noqa: F401
from .traffic import make_traffic_class, make_traffic_sim  # noqa: F401

"""Hand-coded traffic simulator — the validation baseline for Table 2.

The paper validates its BRASIL reimplementation of MITSIM's lane-changing
and acceleration models against MITSIM itself by comparing aggregate lane
statistics (change frequency, average density, average velocity) with
RMSPE.  MITSIM is not available here, so this module plays its role: an
*independent, hand-written* numpy implementation of the same driver models
(same equations as sims/traffic.py, different codebase, different RNG
stream).  benchmarks/table2_validation.py compares the two exactly the way
App. C does.

It is also the "hand-coded simulation" reference for the single-node
performance comparison (Fig. 3): a tight numpy loop with its own nearest-
neighbor search.
"""

from __future__ import annotations

import dataclasses

import numpy as np

BIG = 1.0e30


@dataclasses.dataclass
class OracleParams:
    length: float = 4000.0
    n_lanes: int = 4
    lookahead: float = 200.0
    vmax: float = 30.0
    dt: float = 1.0
    a_acc: float = 2.0
    b_dec: float = 4.0
    k_follow: float = 0.6
    h_upper: float = 2.0
    h_lower: float = 0.6
    g_min: float = 4.0
    g_lead_safe: float = 10.0
    g_rear_safe: float = 8.0
    w_v: float = 1.0
    w_g: float = 0.05
    lc_threshold: float = 2.0
    p_lc: float = 0.6
    right_reluctance: float = 10.0


def _wdelta(d, length):
    return d - length * np.floor(d / length + 0.5)


class TrafficOracle:
    def __init__(self, params: OracleParams, seed: int = 1234):
        self.p = params
        self.rs = np.random.RandomState(seed)

    def step(self, x, lane, v):
        """One tick; returns (x', lane', v', lane_changes mask)."""
        p = self.p
        n = len(x)
        # pairwise wrapped deltas within lookahead
        d = _wdelta(x[None, :] - x[:, None], p.length)  # d[i, j] = j relative to i
        dlane = lane[None, :] - lane[:, None]
        np.fill_diagonal(d, np.inf)
        vis = np.abs(d) <= p.lookahead

        def lead_gap(lane_sel):
            mask = vis & lane_sel & (d > 0)
            dd = np.where(mask, d, BIG)
            j = np.argmin(dd, axis=1)
            gap = dd[np.arange(n), j]
            vlead = np.where(gap < BIG / 2, v[j], 0.0)
            return gap, vlead

        def rear_gap(lane_sel):
            mask = vis & lane_sel & (d < 0)
            dd = np.where(mask, -d, BIG)
            j = np.argmin(dd, axis=1)
            return dd[np.arange(n), j]

        same = np.abs(dlane) < 0.5
        left = (dlane < -0.5) & (dlane > -1.5)
        right = (dlane > 0.5) & (dlane < 1.5)

        gap_s, vlead_s = lead_gap(same)
        gap_l, _ = lead_gap(left)
        gap_r, _ = lead_gap(right)
        rear_l = rear_gap(left)
        rear_r = rear_gap(right)

        def lane_avgv(lane_sel):
            mask = vis & lane_sel
            cnt = mask.sum(axis=1)
            sumv = (mask * v[None, :]).sum(axis=1)
            return np.where(cnt > 0, sumv / np.maximum(cnt, 1), p.vmax)

        avgv_s = lane_avgv(same)
        avgv_l = lane_avgv(left)
        avgv_r = lane_avgv(right)

        # car following
        none_ahead = gap_s > BIG / 2
        free = none_ahead | (gap_s > p.g_min + v * p.h_upper)
        emergency = (~none_ahead) & (gap_s < p.g_min + v * p.h_lower)
        v_free = np.minimum(p.vmax, v + p.a_acc * p.dt)
        v_follow = v + p.k_follow * (vlead_s - v) * p.dt
        v_emerg = np.maximum(0.0, np.minimum(vlead_s, v - p.b_dec * p.dt))
        v_new = np.where(free, v_free, np.where(emergency, v_emerg, v_follow))
        v_new = np.maximum(0.0, v_new)

        # lane selection
        cap = p.lookahead
        u_s = p.w_v * avgv_s + p.w_g * np.minimum(gap_s, cap)
        u_l = p.w_v * avgv_l + p.w_g * np.minimum(gap_l, cap)
        u_r = (
            p.w_v * avgv_r
            + p.w_g * np.minimum(gap_r, cap)
            - np.where(lane + 1 > p.n_lanes - 1.5, p.right_reluctance, 0.0)
        )
        valid_l = lane > 0.5
        valid_r = lane < p.n_lanes - 1.5
        safe_l = (gap_l > p.g_lead_safe) & (rear_l > p.g_rear_safe)
        safe_r = (gap_r > p.g_lead_safe) & (rear_r > p.g_rear_safe)
        want_l = valid_l & safe_l & (u_l > u_s + p.lc_threshold)
        want_r = valid_r & safe_r & (u_r > u_s + p.lc_threshold)
        go = self.rs.uniform(size=n) < p.p_lc
        dl = np.where(
            want_l & (~want_r | (u_l >= u_r)) & go,
            -1.0,
            np.where(want_r & go, 1.0, 0.0),
        )
        lane_new = np.clip(lane + dl, 0, p.n_lanes - 1)
        x_new = np.mod(x + v * p.dt, p.length)
        return x_new, lane_new, v_new, dl != 0


def lane_statistics(x, lane, v, changes, n_lanes: int, length: float):
    """Per-lane (density, mean velocity, change count) for one tick."""
    out = []
    for ln in range(n_lanes):
        m = np.abs(lane - ln) < 0.5
        dens = m.sum() / length * 1000.0  # vehicles per km
        vel = v[m].mean() if m.any() else 0.0
        chg = np.sum(changes & m)
        out.append((dens, vel, chg))
    return np.asarray(out)  # [n_lanes, 3]


def rmspe(a: np.ndarray, b: np.ndarray) -> float:
    """Relative mean square percentage error (App. C's goodness-of-fit)."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    denom = np.where(np.abs(a) > 1e-9, a, 1e-9)
    return float(np.sqrt(np.mean(((a - b) / denom) ** 2)))

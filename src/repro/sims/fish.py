"""Couzin fish-school simulation (paper §5.1 / App. C; Couzin et al. 2005).

Zonal model: repulsion inside radius α (highest priority), attraction +
alignment between α and the visibility radius ρ.  *Informed individuals*
carry a preferred direction (px, py ≠ 0) balanced against the social vector
with weight ω — two informed subgroups pulling in different directions make
the school's spatial distribution drift over time, which is exactly what
exercises the load balancer (paper Fig. 7/8).

All emissions are local (gather-form), matching the paper's observation
that the fish simulation needs only a single reducer per node.
"""

from __future__ import annotations

import numpy as np

from ..brasil import AgentClass, Eff, Other, Param, Self, rand_normal, sqrt, where
from ..core.engine import Simulation


def make_fish_class(
    rho: float = 1.0,
    alpha: float = 0.15,
    speed: float = 0.05,
    omega: float = 0.5,
    noise: float = 0.05,
) -> AgentClass:
    F = AgentClass("Fish", position=("x", "y"), visibility=(rho, rho), radius=rho)
    F.state("x", reach=speed).state("y", reach=speed)
    F.state("hx").state("hy")          # heading (unit)
    F.state("px").state("py")          # preferred direction (0 for uninformed)
    for e in ("rx", "ry", "ax", "ay", "ox", "oy", "cnt_r", "cnt_a"):
        F.effect(e, "sum")
    F.param("speed", speed).param("omega", omega).param("noise", noise)
    F.param("alpha", alpha)

    eps = 1e-6
    dx = Other("x") - Self("x")
    dy = Other("y") - Self("y")
    dist = sqrt(dx * dx + dy * dy) + eps
    near = dist < Param("alpha")

    # repulsion zone (priority)
    F.emit("self", "rx", -dx / dist, where=near)
    F.emit("self", "ry", -dy / dist, where=near)
    F.emit("self", "cnt_r", 1.0, where=near)
    # attraction + orientation zone
    F.emit("self", "ax", dx / dist, where=~near)
    F.emit("self", "ay", dy / dist, where=~near)
    F.emit("self", "ox", Other("hx"), where=~near)
    F.emit("self", "oy", Other("hy"), where=~near)
    F.emit("self", "cnt_a", 1.0, where=~near)

    # update: social vector, informed bias, noise, renormalize
    repulsed = Eff("cnt_r") > 0.5
    sx = where(repulsed, Eff("rx"), Eff("ax") + Eff("ox"))
    sy = where(repulsed, Eff("ry"), Eff("ay") + Eff("oy"))
    lonely = (Eff("cnt_r") + Eff("cnt_a")) < 0.5
    sx = where(lonely, Self("hx"), sx)
    sy = where(lonely, Self("hy"), sy)
    dxp = sx + Param("omega") * Self("px") + Param("noise") * rand_normal()
    dyp = sy + Param("omega") * Self("py") + Param("noise") * rand_normal()
    norm = sqrt(dxp * dxp + dyp * dyp) + eps
    F.update("hx", dxp / norm)
    F.update("hy", dyp / norm)
    # positions move with the OLD heading (state-effect: updates read states
    # of tick t, not each other)
    F.update("x", Self("x") + Param("speed") * Self("hx"))
    F.update("y", Self("y") + Param("speed") * Self("hy"))
    return F


def make_fish_sim(
    world: tuple[float, float] = (40.0, 10.0),
    **kw,
) -> Simulation:
    F = make_fish_class(**kw)
    return Simulation.build(F, world_lo=(0.0, 0.0), world_hi=world)


def init_school(
    sim: Simulation,
    n: int,
    capacity: int,
    seed: int = 0,
    informed_fraction: float = 0.1,
    directions=((1.0, 0.0), (-1.0, 0.0)),
    center: tuple[float, float] | None = None,
    spread: float = 2.0,
):
    """Two informed subgroups with opposing preferred directions (Fig. 7)."""
    rs = np.random.RandomState(seed)
    lo, hi = sim.world_lo, sim.world_hi
    cx = (lo[0] + hi[0]) / 2 if center is None else center[0]
    cy = (lo[1] + hi[1]) / 2 if center is None else center[1]
    x = rs.normal(cx, spread, n).clip(lo[0], hi[0]).astype(np.float32)
    y = rs.normal(cy, spread, n).clip(lo[1], hi[1]).astype(np.float32)
    theta = rs.uniform(0, 2 * np.pi, n)
    hx = np.cos(theta).astype(np.float32)
    hy = np.sin(theta).astype(np.float32)
    px = np.zeros(n, np.float32)
    py = np.zeros(n, np.float32)
    n_inf = int(n * informed_fraction)
    half = n_inf // 2
    px[:half], py[:half] = directions[0]
    px[half:n_inf], py[half:n_inf] = directions[1]
    return sim.init_population(
        capacity, oid=np.arange(n), x=x, y=y, hx=hx, hy=hy, px=px, py=py
    )

"""Traffic simulation with MITSIM's lane-selection and car-following models
(paper §5.1 / App. C; Yang & Koutsopoulos 1999).

Per tick each driver (agent) inspects, within a fixed lookahead distance ρ
(the paper fixes ρ=200 to enable spatial indexing, App. C):

  * the lead and rear vehicles in her current / left / right lanes
    (``min_by`` effects keyed by gap — decomposable, order-independent),
  * per-lane average velocity and density (``sum`` effects),

then (update phase) computes a lane utility, makes a probabilistic lane
change gated by lead/rear safety gaps (with the MITSIM right-most-lane
reluctance factor, App. C), and adapts velocity with a three-regime
car-following model (free flow / following / emergency braking).

The road is a circular segment (x wraps at length L) so the population and
density are stationary — the standard benchmarking variant of MITSIM's
constant-upstream-inflow linear segment.  All effects are local gathers, so
BRACE runs it with a single reduce pass (paper §5.1: "Neither of these
simulations uses non-local effect assignments").
"""

from __future__ import annotations

import numpy as np

from ..brasil import (
    AgentClass,
    Eff,
    Other,
    Param,
    Self,
    abs_,
    clip,
    floor,
    maximum,
    minimum,
    rand_uniform,
    to_float,
    where,
)
from ..core.engine import Simulation

BIG = 1.0e30  # "no vehicle found" marker from the min_by identity


def _wdelta(d, period: float):
    """Shortest signed delta on the circular road (AST-level)."""
    return d - period * floor(d / period + 0.5)


def make_traffic_class(
    length: float = 4000.0,
    n_lanes: int = 4,
    lookahead: float = 200.0,
    vmax: float = 30.0,
    dt: float = 1.0,
    a_acc: float = 2.0,
    b_dec: float = 4.0,
    k_follow: float = 0.6,
    h_upper: float = 2.0,   # free-flow headway (s)
    h_lower: float = 0.6,   # emergency headway (s)
    g_min: float = 4.0,     # minimum standstill gap (m)
    g_lead_safe: float = 10.0,
    g_rear_safe: float = 8.0,
    w_v: float = 1.0,
    w_g: float = 0.05,
    lc_threshold: float = 2.0,
    p_lc: float = 0.6,
    right_reluctance: float = 10.0,
) -> AgentClass:
    T = AgentClass("Car", position=("x", "lane"), visibility=(lookahead, 1.2))
    T.state("x", reach=vmax * dt * 1.5, wrap=length)
    T.state("lane", reach=1.0)
    T.state("v")
    for p, v in dict(
        vmax=vmax, dt=dt, a_acc=a_acc, b_dec=b_dec, k_follow=k_follow,
        h_upper=h_upper, h_lower=h_lower, g_min=g_min,
        g_lead_safe=g_lead_safe, g_rear_safe=g_rear_safe,
        w_v=w_v, w_g=w_g, lc_threshold=lc_threshold, p_lc=p_lc,
        right_reluctance=right_reluctance, lookahead=lookahead,
        n_lanes=float(n_lanes),
    ).items():
        T.param(p, v)

    # lead/rear vehicle per lane (min_by gap), lane speed/density sums
    for lane_tag in ("s", "l", "r"):
        T.effect(f"lead_{lane_tag}", "min_by", payload=["v"])
        T.effect(f"rear_{lane_tag}", "min_by", payload=["v"])
        T.effect(f"cnt_{lane_tag}", "sum")
        T.effect(f"sumv_{lane_tag}", "sum")

    d = _wdelta(Other("x") - Self("x"), length)
    dlane = Other("lane") - Self("lane")
    same = abs_(dlane) < 0.5
    left = (dlane < -0.5) & (dlane > -1.5)
    right = (dlane > 0.5) & (dlane < 1.5)
    ahead = d > 0.0
    behind = d < 0.0

    for tag, lane_sel in (("s", same), ("l", left), ("r", right)):
        T.emit("self", f"lead_{tag}", {"key": d, "v": Other("v")},
               where=lane_sel & ahead)
        T.emit("self", f"rear_{tag}", {"key": -d, "v": Other("v")},
               where=lane_sel & behind)
        T.emit("self", f"cnt_{tag}", 1.0, where=lane_sel)
        T.emit("self", f"sumv_{tag}", Other("v"), where=lane_sel)

    # ---- update phase -------------------------------------------------------
    def lane_stats(tag):
        gap_lead = Eff(f"lead_{tag}")           # key = gap; BIG when none
        vlead = Eff(f"lead_{tag}", "v")
        gap_rear = Eff(f"rear_{tag}")
        cnt = Eff(f"cnt_{tag}")
        avgv = where(cnt > 0.5, Eff(f"sumv_{tag}") / maximum(cnt, 1.0), Param("vmax"))
        return gap_lead, vlead, gap_rear, avgv

    gap_s, vlead_s, _, avgv_s = lane_stats("s")
    gap_l, _, rear_l, avgv_l = lane_stats("l")
    gap_r, _, rear_r, avgv_r = lane_stats("r")

    v = Self("v")
    lane = Self("lane")

    # car following: free flow / following / emergency (MITSIM regimes)
    none_ahead = gap_s > BIG * 0.5
    free = none_ahead | (gap_s > Param("g_min") + v * Param("h_upper"))
    emergency = (~none_ahead) & (gap_s < Param("g_min") + v * Param("h_lower"))
    v_free = minimum(Param("vmax"), v + Param("a_acc") * Param("dt"))
    v_follow = v + Param("k_follow") * (vlead_s - v) * Param("dt")
    v_emerg = maximum(0.0, minimum(vlead_s, v - Param("b_dec") * Param("dt")))
    v_new = where(free, v_free, where(emergency, v_emerg, v_follow))

    # lane utilities (clamped gaps) + right-most-lane reluctance
    cap = Param("lookahead")
    u_s = Param("w_v") * avgv_s + Param("w_g") * minimum(gap_s, cap)
    u_l = Param("w_v") * avgv_l + Param("w_g") * minimum(gap_l, cap)
    u_r = (
        Param("w_v") * avgv_r
        + Param("w_g") * minimum(gap_r, cap)
        - where(lane + 1.0 > Param("n_lanes") - 1.5, Param("right_reluctance"), 0.0)
    )

    valid_l = lane > 0.5
    valid_r = lane < Param("n_lanes") - 1.5
    safe_l = (gap_l > Param("g_lead_safe")) & (rear_l > Param("g_rear_safe"))
    safe_r = (gap_r > Param("g_lead_safe")) & (rear_r > Param("g_rear_safe"))
    want_l = valid_l & safe_l & (u_l > u_s + Param("lc_threshold"))
    want_r = valid_r & safe_r & (u_r > u_s + Param("lc_threshold"))
    go = rand_uniform() < Param("p_lc")
    dl = where(
        want_l & (~want_r | (u_l >= u_r)) & go,
        -1.0,
        where(want_r & go, 1.0, 0.0),
    )
    T.update("lane", clip(lane + dl, 0.0, Param("n_lanes") - 1.0))
    T.update("v", maximum(0.0, v_new))
    # positions advance with the tick-t velocity (state-effect semantics)
    T.update("x", Self("x") + v * Param("dt"))
    return T


def make_traffic_sim(length: float = 4000.0, n_lanes: int = 4, **kw) -> Simulation:
    T = make_traffic_class(length=length, n_lanes=n_lanes, **kw)
    return Simulation.build(
        T, world_lo=(0.0, 0.0), world_hi=(length, float(n_lanes - 1))
    )


def init_traffic(
    sim: Simulation,
    n: int,
    capacity: int,
    seed: int = 0,
    length: float | None = None,
    n_lanes: int = 4,
    v0: float = 20.0,
):
    rs = np.random.RandomState(seed)
    length = length if length is not None else sim.world_hi[0]
    x = rs.uniform(0, length, n).astype(np.float32)
    lane = rs.randint(0, n_lanes, n).astype(np.float32)
    v = rs.uniform(0.5 * v0, 1.2 * v0, n).astype(np.float32)
    return sim.init_population(capacity, oid=np.arange(n), x=x, lane=lane, v=v)

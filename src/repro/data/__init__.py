from .pipeline import DataConfig, SyntheticTokens, make_batch_specs  # noqa: F401

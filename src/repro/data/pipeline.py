"""Synthetic sharded token pipeline.

Deterministic per (seed, step, shard): every data-parallel host draws a
disjoint, reproducible slice of the global batch, so a restarted run
(fault-tolerance path) replays the same stream.  Double-buffered prefetch
overlaps host generation with device steps.

The generator is a mixture of Zipf-distributed unigrams and short repeated
motifs — enough structure that the CE loss falls measurably within a few
hundred steps (examples/train_lm.py), while remaining dependency-free.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    motif_prob: float = 0.5


class SyntheticTokens:
    """Iterable over {tokens, labels} host batches (numpy)."""

    def __init__(self, cfg: DataConfig, shard: int = 0, n_shards: int = 1,
                 prefetch: int = 2):
        if cfg.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.cfg = cfg
        self.shard = shard
        self.n_shards = n_shards
        self.local_batch = cfg.global_batch // n_shards
        self._motifs = self._make_motifs()
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = 0
        self._thread: threading.Thread | None = None

    def _make_motifs(self):
        rs = np.random.RandomState(self.cfg.seed + 7)
        return rs.randint(
            0, self.cfg.vocab, size=(64, self.cfg.motif_len)
        ).astype(np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rs = np.random.RandomState(
            (cfg.seed * 1_000_003 + step) % (2**31) + self.shard
        )
        b, s = self.local_batch, cfg.seq_len
        # Zipf unigrams (clipped into vocab)
        toks = rs.zipf(cfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
        # overwrite random spans with repeated motifs (learnable structure)
        n_spans = int(s * cfg.motif_prob / cfg.motif_len)
        for i in range(b):
            for _ in range(max(1, n_spans)):
                m = self._motifs[rs.randint(0, len(self._motifs))]
                start = rs.randint(0, s + 1 - cfg.motif_len)
                toks[i, start:start + cfg.motif_len] = m
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # -- background prefetch ---------------------------------------------------
    def start(self):
        def worker():
            step = self._step
            while True:
                self._q.put(self.batch_at(step))
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def __next__(self):
        if self._thread is None:
            out = self.batch_at(self._step)
        else:
            out = self._q.get()
        self._step += 1
        return out

    def __iter__(self):
        return self


def make_batch_specs(vocab: int, seq_len: int, global_batch: int):
    """ShapeDtypeStructs for a training batch (dry-run input stand-ins)."""
    import jax.numpy as jnp

    shape = (global_batch, seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
        "labels": jax.ShapeDtypeStruct(shape, jnp.int32),
    }

"""Dry-run machinery: lower + compile every (arch × shape × mesh) cell and
extract memory/cost/roofline evidence.  Pure library — no jax device-count
side effects; the ``repro.launch.dryrun`` entrypoint sets XLA_FLAGS first.

For each cell we build the *step function the production launcher runs*
(train_step / prefill / decode_step), attach explicit NamedShardings for
every input, and ``jit(...).lower(...).compile()`` against
ShapeDtypeStructs — no arrays are ever allocated.  ``memory_analysis()``
proves the cell fits per-device HBM; ``cost_analysis()`` + the HLO
collective parse feed EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ArchConfig, ShapeSpec, get_arch, supports
from ..dist import hlo_analysis
from ..dist.activation_sharding import activation_sharding, default_roles
from ..dist.sharding import MeshAxes, batch_pspec, param_pspec, tree_shardings
from ..models.zoo import Model, build_model
from ..train.optimizer import AdamWConfig
from ..train.train_step import TrainConfig, TrainState, init_train_state, make_train_step

Array = jax.Array

HBM_PER_DEVICE = 16 * 1024**3  # v5e: 16 GiB


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell (the deliverable's ``input_specs()``)."""
    b, s = shape.global_batch, shape.seq_len
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, s // cfg.enc_frames_divisor, cfg.d_model), dt
            )
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, s // cfg.enc_frames_divisor, cfg.d_model), dt
            )
        return batch
    # decode: one new token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# cache shardings
# ---------------------------------------------------------------------------

def _cache_pspec_for(name: str, shape: tuple, mesh: Mesh, axes: MeshAxes) -> P:
    """Decode-cache leaf rules (leading dim = layer stack, replicated).

    KV caches [L, B, T, Hkv, Dh]: batch over batch axes when divisible;
    otherwise the sequence axis carries the parallelism (context sharding —
    flash-decoding split-KV, GSPMD inserts the softmax-stat all-reduce).
    Recurrent states shard batch и heads/channels.
    """
    from ..dist.sharding import _guard  # shared divisibility guard

    nd = len(shape)
    spec: list = [None] * nd

    def batch_axes_for(dim):
        got = _guard(mesh, shape[dim], axes.batch)
        if got is None:
            got = _guard(mesh, shape[dim], (axes.batch[-1],))
        return got

    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
        b_dim, t_dim, h_dim = 1, 2, 3
        b_ax = batch_axes_for(b_dim)
        spec[b_dim] = b_ax
        h_ax = _guard(mesh, shape[h_dim], axes.tensor)
        if h_ax is not None:
            spec[h_dim] = h_ax
            if b_ax is None:
                spec[t_dim] = _guard(mesh, shape[t_dim], ("data",))
        else:
            remaining = ("data", axes.tensor) if b_ax is None else (axes.tensor,)
            spec[t_dim] = _guard(mesh, shape[t_dim], remaining)
            if spec[t_dim] is None:
                spec[t_dim] = _guard(mesh, shape[t_dim], (axes.tensor,))
    elif name in ("ssm", "wkv"):  # [L, B, H, N, P]
        spec[1] = batch_axes_for(1)
        spec[2] = _guard(mesh, shape[2], axes.tensor)
    elif name == "conv":  # [L, B, K, C]
        spec[1] = batch_axes_for(1)
        spec[3] = _guard(mesh, shape[3], axes.tensor)
    elif name in ("shift_t", "shift_c"):  # [L, B, D]
        spec[1] = batch_axes_for(1)
        spec[2] = _guard(mesh, shape[2], axes.tensor)
    return P(*spec)


def cache_shardings(cache_struct: Any, mesh: Mesh, axes: MeshAxes) -> Any:
    def one(path, leaf):
        names = [
            str(p.key) for p in path if isinstance(p, jax.tree_util.DictKey)
        ]
        return NamedSharding(
            mesh, _cache_pspec_for(names[-1], leaf.shape, mesh, axes)
        )

    return jax.tree_util.tree_map_with_path(one, cache_struct)


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    skipped: bool = False
    reason: str = ""
    compile_s: float = 0.0
    memory: dict | None = None
    roofline: dict | None = None            # trip-count-corrected (see below)
    roofline_raw: dict | None = None        # scanned-program cost_analysis
    model_flops_per_device: float = 0.0
    useful_ratio: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# scan-trip-count correction
# ---------------------------------------------------------------------------
# XLA's cost_analysis counts a while-loop body ONCE, so the layer scan
# undercounts FLOPs/bytes/collectives by ~n_layers (verified on granite-8b:
# reported FLOPs × 36 ≈ 6·N·D).  We therefore compile two *unrolled* small
# variants (L_a, L_b layers, scan_layers=False) and extrapolate the costs
# linearly in the layer count: cost(L) = const + slope·L.  Per-layer costs
# are layer-independent by construction (identical shapes), and the const
# term captures embed/head/loss — so the fit is exact up to the MoE-router
# noise.  Memory analysis stays with the real scanned program (where scan
# matters).

def _analysis_points(cfg: ArchConfig) -> tuple[int, int]:
    if cfg.family == "hybrid":
        p = max(1, cfg.shared_attn_period)
        return p, 2 * p  # one / two (period mamba + shared attn) units
    return 2, 4


def _unrolled_cfg(cfg: ArchConfig, n_layers: int) -> ArchConfig:
    # microbatches=1: the µbatch scan is ALSO a while loop whose body XLA
    # counts once; per-token costs are identical at mb=1, so the unrolled
    # cost points stay comparable
    kw = dict(n_layers=n_layers, scan_layers=False, train_microbatches=1)
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _measure(compiled, n_dev: int) -> dict:
    roof = hlo_analysis.analyze(compiled, n_dev)
    return {
        "flops": roof.flops_per_device,
        "bytes": roof.bytes_per_device,
        "coll": dict(roof.coll_breakdown),
    }


def _extrapolate(ca: dict, cb: dict, la: int, lb: int, l_full: float) -> dict:
    def lin(a, b):
        slope = (b - a) / (lb - la)
        return max(0.0, a + slope * (l_full - la))

    coll = {
        k: lin(ca["coll"].get(k, 0), cb["coll"].get(k, 0)) for k in ca["coll"]
    }
    return {
        "flops": lin(ca["flops"], cb["flops"]),
        "bytes": lin(ca["bytes"], cb["bytes"]),
        "coll": coll,
    }


def _lower_train(model: Model, cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                 axes: MeshAxes, extra_jit_kwargs: dict | None = None):
    tc = TrainConfig(
        optimizer=AdamWConfig(), microbatches=cfg.train_microbatches
    )
    state_struct = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0), tc)
    )
    state_sh = TrainState(
        params=tree_shardings(state_struct.params, mesh, axes),
        opt={
            "master": tree_shardings(state_struct.opt["master"], mesh, axes),
            "m": tree_shardings(state_struct.opt["m"], mesh, axes),
            "v": tree_shardings(state_struct.opt["v"], mesh, axes),
            "step": NamedSharding(mesh, P()),
        },
        err=None,
    )
    batch_struct = input_specs(cfg, shape)
    batch_sh = {
        k: NamedSharding(
            mesh, batch_pspec(mesh, axes, v.shape[0], len(v.shape))
        )
        for k, v in batch_struct.items()
    }
    metrics_sh = {
        "grad_norm": NamedSharding(mesh, P()),
        "lr": NamedSharding(mesh, P()),
        "loss": NamedSharding(mesh, P()),
    }
    step = make_train_step(model, tc, mesh=mesh, batch_axes=axes.batch)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        **(extra_jit_kwargs or {}),
    )
    return jitted.lower(state_struct, batch_struct)


def _lower_prefill(model: Model, cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                   axes: MeshAxes):
    params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    params_sh = tree_shardings(params_struct, mesh, axes)
    specs = input_specs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len

    if cfg.family == "encdec":
        fn = lambda p, batch: model.prefill(p, batch, s)
        batch_struct = {"tokens": specs["tokens"], "frames": specs["frames"]}
        batch_sh = {
            k: NamedSharding(mesh, batch_pspec(mesh, axes, v.shape[0], len(v.shape)))
            for k, v in batch_struct.items()
        }
        args = (params_struct, batch_struct)
        in_sh = (params_sh, batch_sh)
    else:
        fn = lambda p, tokens: model.prefill(p, tokens, s)
        tok = specs["tokens"]
        tok_sh = NamedSharding(mesh, batch_pspec(mesh, axes, b, 2))
        args = (params_struct, tok)
        in_sh = (params_sh, tok_sh)

    cache_struct = jax.eval_shape(lambda *a: fn(*a)[1], *args)
    cache_sh = cache_shardings(cache_struct, mesh, axes)
    logits_sh = NamedSharding(mesh, batch_pspec(mesh, axes, b, 2))
    out_sh = (logits_sh, cache_sh, NamedSharding(mesh, P()))
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    return jitted.lower(*args)


def _lower_decode(model: Model, cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                  axes: MeshAxes):
    params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    params_sh = tree_shardings(params_struct, mesh, axes)
    b, s = shape.global_batch, shape.seq_len

    if cfg.family == "encdec":
        s_enc = s // cfg.enc_frames_divisor
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(b, s, s_enc)
        )
    else:
        cache_struct = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_sh = cache_shardings(cache_struct, mesh, axes)

    specs = input_specs(cfg, shape)
    tok_sh = NamedSharding(mesh, batch_pspec(mesh, axes, b, 1))
    pos_sh = NamedSharding(mesh, P())
    logits_sh = NamedSharding(mesh, batch_pspec(mesh, axes, b, 2))

    fn = lambda p, cache, tok, pos: model.decode_step(p, cache, tok, pos)
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
    )
    return jitted.lower(params_struct, cache_struct, specs["token"], specs["pos"])


def run_cell(arch: str, shape_name: str, mesh: Mesh, mesh_name: str,
             analysis: bool = True) -> CellResult:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = supports(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_name, ok=False, skipped=True,
                          reason=reason)
    axes = MeshAxes.for_mesh(mesh)
    model = build_model(cfg)

    def lower_for(c: ArchConfig):
        m = build_model(c)
        if shape.kind == "train":
            return _lower_train(m, c, shape, mesh, axes)
        if shape.kind == "prefill":
            return _lower_prefill(m, c, shape, mesh, axes)
        return _lower_decode(m, c, shape, mesh, axes)

    t0 = time.time()
    try:
        with activation_sharding(mesh, default_roles(axes.batch)):
            compiled = lower_for(cfg).compile()
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        return CellResult(arch, shape_name, mesh_name, ok=False,
                          reason=f"{type(e).__name__}: {e}"[:2000],
                          compile_s=time.time() - t0)
    compile_s = time.time() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_estimate_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ),
        "hbm_per_device": HBM_PER_DEVICE,
    }
    roof_raw = hlo_analysis.analyze(compiled, mesh.devices.size)

    # trip-count-corrected costs via two unrolled small variants
    roofline = roof_raw.as_dict()
    try:
        if analysis:
            la, lb = _analysis_points(cfg)
            with activation_sharding(mesh, default_roles(axes.batch)):
                pa = _measure(lower_for(_unrolled_cfg(cfg, la)).compile(),
                              mesh.devices.size)
                pb = _measure(lower_for(_unrolled_cfg(cfg, lb)).compile(),
                              mesh.devices.size)
            ext = _extrapolate(pa, pb, la, lb, cfg.n_layers)
            corrected = hlo_analysis.Roofline(
                flops_per_device=ext["flops"],
                bytes_per_device=ext["bytes"],
                coll_bytes_per_device=float(sum(ext["coll"].values())),
                coll_breakdown={k: int(v) for k, v in ext["coll"].items()},
                n_devices=mesh.devices.size,
            )
            roofline = corrected.as_dict()
            roofline["correction"] = "unrolled-2pt-extrapolation"
    except Exception as e:  # noqa: BLE001 — fall back to raw costs
        roofline["correction"] = f"failed: {type(e).__name__}: {e}"[:300]

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf_global = hlo_analysis.model_flops(
        cfg.param_count(), cfg.active_param_count(), tokens, shape.kind
    )
    mf_dev = mf_global / mesh.devices.size
    flops_dev = roofline["flops_per_device"]
    useful = mf_dev / flops_dev if flops_dev else 0.0
    return CellResult(
        arch, shape_name, mesh_name, ok=True, compile_s=compile_s,
        memory=mem, roofline=roofline, roofline_raw=roof_raw.as_dict(),
        model_flops_per_device=mf_dev, useful_ratio=useful,
    )


def run_cells(archs, shapes, meshes: dict[str, Mesh], out_dir: str | None = None,
              verbose: bool = True, analysis: bool = True) -> list[CellResult]:
    results = []
    for mesh_name, mesh in meshes.items():
        for arch in archs:
            for shape_name in shapes:
                # the roofline table is single-pod; multi-pod cells compile
                # as proof but skip the 2-pt cost extrapolation
                res = run_cell(arch, shape_name, mesh, mesh_name,
                               analysis=analysis and mesh_name == "single")
                results.append(res)
                if verbose:
                    _print_result(res)
                if out_dir:
                    os.makedirs(out_dir, exist_ok=True)
                    fn = f"{arch}__{shape_name}__{mesh_name}.json"
                    with open(os.path.join(out_dir, fn), "w") as f:
                        json.dump(res.as_dict(), f, indent=2)
    return results


def _print_result(r: CellResult):
    if r.skipped:
        print(f"[SKIP] {r.arch:18s} {r.shape:12s} {r.mesh:6s} — {r.reason[:70]}")
    elif not r.ok:
        print(f"[FAIL] {r.arch:18s} {r.shape:12s} {r.mesh:6s} — {r.reason[:160]}")
    else:
        m = r.memory
        roof = r.roofline
        peak_gib = m["peak_estimate_bytes"] / 2**30
        print(
            f"[ OK ] {r.arch:18s} {r.shape:12s} {r.mesh:6s} "
            f"compile={r.compile_s:6.1f}s peak={peak_gib:6.2f}GiB "
            f"Tc={roof['t_compute_s']:.3e} Tm={roof['t_memory_s']:.3e} "
            f"Tcoll={roof['t_collective_s']:.3e} dom={roof['dominant']:10s} "
            f"useful={r.useful_ratio:.2f}"
        )

"""End-to-end training driver.

On real hardware this runs under the production mesh; on CPU it drives the
same code path with a small mesh/model (examples/train_lm.py).  Features:
sharded synthetic data pipeline, AdamW + schedule, step checkpoints with
elastic restore, optional int8 gradient compression across 'pod'.

    python -m repro.launch.train --arch granite-8b --steps 100 ...
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_loop(
    arch: str,
    steps: int,
    *,
    reduced_for_cpu: bool = True,
    global_batch: int = 8,
    seq_len: int = 128,
    lr: float = 3e-3,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 50,
    restore: bool = False,
    log_every: int = 10,
    seed: int = 0,
):
    from ..configs.base import get_arch, reduced
    from ..data.pipeline import DataConfig, SyntheticTokens
    from ..models.zoo import build_model
    from ..train.optimizer import AdamWConfig
    from ..train.train_step import TrainConfig, init_train_state, make_train_step

    cfg = get_arch(arch)
    if reduced_for_cpu:
        cfg = reduced(
            cfg, n_layers=4, d_model=128, n_heads=4, d_head=32, d_ff=512,
            vocab=512,
        )
    model = build_model(cfg)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps)
    )
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))

    data = SyntheticTokens(
        DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
                   seed=seed)
    ).start()

    state = init_train_state(model, jax.random.PRNGKey(seed), tc)
    start_step = 0
    mgr = None
    if checkpoint_dir:
        from .ckpt_train import TrainCheckpointManager

        mgr = TrainCheckpointManager(checkpoint_dir)
        if restore and mgr.latest_step() is not None:
            state, start_step = mgr.restore(state)
            print(f"restored from step {start_step}")
            for _ in range(start_step):  # replay the data stream position
                next(data)

    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        if mgr and checkpoint_every and (step + 1) % checkpoint_every == 0:
            mgr.save(state, step + 1)
    if mgr:
        mgr.save(state, steps)
    return losses


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-8b")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt", default=None)
    p.add_argument("--restore", action="store_true")
    p.add_argument("--full-size", action="store_true",
                   help="use the full config (TPU)")
    args = p.parse_args(argv)
    losses = train_loop(
        args.arch, args.steps, reduced_for_cpu=not args.full_size,
        global_batch=args.batch, seq_len=args.seq, lr=args.lr,
        checkpoint_dir=args.ckpt, restore=args.restore,
    )
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"loss: first10={first:.4f} last10={last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()

"""Production meshes.

Single pod: 16×16 = 256 chips (data × model).
Multi-pod:  2×16×16 = 512 chips (pod × data × model) — the 'pod' axis
carries the data-parallel replica groups whose gradient all-reduce crosses
the inter-pod links (and is the target of the int8-compression option).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this automatically)"
        )
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
            devices=devices[:n],
        )
    except TypeError:  # older make_mesh without devices kwarg
        arr = np.asarray(devices[:n]).reshape(shape)
        return jax.sharding.Mesh(arr, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for in-CI dry-run tests on few fake devices."""
    n = int(np.prod(shape))
    arr = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)

import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run entrypoint.

The two lines above run before ANY other import (jax locks the device
count on first init): 512 placeholder CPU devices back the production
meshes.  Everything else lives in dryrun_lib (importable without the env
side effect for small-mesh tests).

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # all 40 cells × both meshes
    python -m repro.launch.dryrun --all --mesh multi
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402


def main(argv=None):
    from repro.configs.base import SHAPES, all_archs
    from repro.launch.dryrun_lib import run_cells
    from repro.launch.mesh import make_production_mesh

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", action="append", help="architecture id(s)")
    p.add_argument("--shape", action="append", help="shape name(s)")
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    p.add_argument("--all", action="store_true", help="all archs × shapes")
    p.add_argument("--out", default="results/dryrun")
    args = p.parse_args(argv)

    archs = all_archs() if (args.all or not args.arch) else args.arch
    shapes = list(SHAPES) if (args.all or not args.shape) else args.shape

    meshes = {}
    if args.mesh in ("single", "both"):
        meshes["single"] = make_production_mesh(multi_pod=False)
    if args.mesh in ("multi", "both"):
        meshes["multi"] = make_production_mesh(multi_pod=True)

    results = run_cells(archs, shapes, meshes, out_dir=args.out)
    n_fail = sum(1 for r in results if not r.ok and not r.skipped)
    n_ok = sum(1 for r in results if r.ok)
    n_skip = sum(1 for r in results if r.skipped)
    print(f"\n{n_ok} ok / {n_skip} documented skips / {n_fail} FAILURES")
    summary = [r.as_dict() for r in results]
    with open(f"{args.out}/summary_{args.mesh}.json", "w") as f:
        json.dump(summary, f, indent=2)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Training checkpoints: step-tagged npz trees with a mesh-agnostic
manifest (elastic restore re-shards on load)."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif hasattr(tree, "__dataclass_fields__"):
        for k in tree.__dataclass_fields__:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


class TrainCheckpointManager:
    def __init__(self, directory: str, keep: int = 2):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def save(self, state, step: int):
        flat = _flatten(state)
        path = os.path.join(self.directory, f"train_{step:010d}")
        tmp = path + ".tmp.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path + ".npz")
        with open(path + ".meta.json", "w") as f:
            json.dump({"step": step}, f)
        self._gc()

    def latest_step(self):
        steps = self._steps()
        return steps[-1] if steps else None

    def _steps(self):
        out = []
        for n in os.listdir(self.directory):
            if n.startswith("train_") and n.endswith(".meta.json"):
                out.append(int(n[len("train_"):-len(".meta.json")]))
        return sorted(out)

    def _gc(self):
        for s in self._steps()[: -self.keep]:
            for suf in (".npz", ".meta.json"):
                try:
                    os.remove(os.path.join(self.directory, f"train_{s:010d}{suf}"))
                except FileNotFoundError:
                    pass

    def restore(self, template_state):
        """Load the latest checkpoint into the template's structure (the
        template carries shapes/shardings — restore re-shards as needed)."""
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no train checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"train_{step:010d}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}

        def rebuild(tree, prefix=""):
            if isinstance(tree, dict):
                return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
            if hasattr(tree, "__dataclass_fields__"):
                kw = {
                    k: rebuild(getattr(tree, k), f"{prefix}{k}/")
                    for k in tree.__dataclass_fields__
                }
                return type(tree)(**kw)
            if tree is None:
                return None
            arr = flat[prefix.rstrip("/")]
            return jnp.asarray(arr, dtype=tree.dtype)

        return rebuild(template_state), step

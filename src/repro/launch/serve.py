"""Serving driver: batched prefill + greedy decode over the model zoo.

    python -m repro.launch.serve --arch granite-8b --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def generate(model, params, tokens, n_gen: int, max_len: int):
    """Greedy decode; returns [B, n_gen] generated ids + tokens/s."""
    logits, cache, pos = jax.jit(
        lambda p, t: model.prefill(p, t, max_len)
    )(params, tokens)
    step = jax.jit(model.decode_step)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(n_gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
    jax.block_until_ready(logits)
    dt = time.time() - t0
    b = tokens.shape[0]
    return jnp.stack(out, axis=1), b * n_gen / dt


def main(argv=None):
    from ..configs.base import get_arch, reduced
    from ..models.zoo import build_model

    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-8b")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--full-size", action="store_true")
    args = p.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    tokens = jnp.asarray(
        rs.randint(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    if cfg.family == "encdec":
        frames = jnp.asarray(
            rs.randn(args.batch, args.prompt_len // 4, cfg.d_model), jnp.float32
        ).astype(params["embed"].dtype)
        tokens = {"frames": frames, "tokens": tokens}
    ids, tps = generate(model, params, tokens, args.gen,
                        args.prompt_len + args.gen)
    print(f"arch={args.arch} generated {ids.shape} at {tps:.1f} tok/s")
    print("first row:", np.asarray(ids[0]).tolist())


if __name__ == "__main__":
    main()

"""Pure-jnp sequential oracle for the RWKV6 wkv recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, logw, u, s0):
    """r/k/v/logw: [B, H, T, K] f32; u: [H, K]; s0: [B, H, K, V].

    out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    Returns (out [B, H, T, K], s_final).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp  # [B, H, K]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = jnp.exp(wt)[..., None] * s + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, logw))
    s_fin, outs = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(outs, 0, 2), s_fin

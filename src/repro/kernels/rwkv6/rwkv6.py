"""RWKV6 wkv recurrence as a Pallas TPU kernel (chunked parallel form).

Grid = (batch·heads, chunks) with chunks sequential; the carried state
S ∈ R^{K×V} lives in VMEM scratch.  Within a chunk the decay-weighted
lower-triangular interaction matrix is formed on the MXU (the SSD trick
applied to RWKV6's data-dependent per-channel decay):

    A[t, m] = Σ_k r[t,k] · exp(cum[t,k] − w[t,k] − cum[m,k]) · k[m,k]   (m < t)
    out     = A·V + (r·exp(cum_excl))·S_in + diag(r·u·k)·V
    S_out   = exp(cum_L) ⊙ S_in + Σ_m (exp(cum_L − cum_m) k_m) v_mᵀ
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)     # [C, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)     # log-decay ≤ 0
    u = u_ref[0].astype(jnp.float32)     # [1, K] (head bonus row)

    cum = jnp.cumsum(w, axis=0)          # [C, K]
    cum_excl = cum - w
    s_in = s_scr[...]                    # [K, V]

    # inter-chunk: out_inter = (r ⊙ exp(cum_excl)) @ S_in
    rd = r * jnp.exp(cum_excl)
    out_inter = jax.lax.dot_general(
        rd, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # intra-chunk lower-triangular attention-like term
    att = jax.lax.dot_general(
        rd, k * jnp.exp(-cum), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [C, C]  att[t, m]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ti > mi, att, 0.0)
    out_intra = jax.lax.dot_general(
        att, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # diagonal bonus
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # [C, 1]
    out_diag = diag * v

    o_ref[0] = (out_inter + out_intra + out_diag).astype(o_ref.dtype)

    # state update
    total = cum[-1:, :]                  # [1, K]
    kd = k * jnp.exp(total - cum)        # [C, K]
    s_new = jnp.exp(total).T * s_in + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    s_scr[...] = s_new


def wkv_pallas(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/logw: [B, H, T, K]; u: [H, K] → out [B, H, T, K].

    (Initial state is zero; the final state can be recovered with one extra
    chunk pass if needed — decode uses the jnp path.)
    """
    b, h, t, kd = r.shape
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"T={t} must be a multiple of chunk={chunk}")
    nc = t // chunk
    bh = b * h

    def flat(a):
        return a.reshape(bh, t, kd)

    u_full = jnp.broadcast_to(u[None], (b, h, kd)).reshape(bh, 1, kd)

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, kd), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda g, ci: (g, ci, 0)),
            pl.BlockSpec((1, 1, kd), lambda g, ci: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, kd), lambda g, ci: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, kd), r.dtype),
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(logw), u_full)
    return out.reshape(b, h, t, kd)

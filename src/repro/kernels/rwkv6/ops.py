"""Jitted wrapper for the RWKV6 wkv kernel."""

from __future__ import annotations

from functools import partial

import jax

from .ref import wkv_ref
from .rwkv6 import wkv_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    return wkv_pallas(r, k, v, logw, u, chunk=chunk, interpret=interpret)


def wkv_reference(r, k, v, logw, u, s0=None):
    import jax.numpy as jnp

    b, h, t, kd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    out, _ = wkv_ref(r, k, v, logw, u, s0)
    return out

"""Jitted wrapper for the flash attention kernel ([B,S,H,D] layout)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q: [B, S, H, D]; k/v: [B, S, Hkv, D] (GQA repeat applied here)."""
    g = q.shape[2] // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return jnp.transpose(out, (0, 2, 1, 3))


def flash_attention_reference(q, k, v, *, causal=True, window=None):
    g = q.shape[2] // k.shape[2]
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return jnp.transpose(out, (0, 2, 1, 3))

"""Flash attention forward as a Pallas TPU kernel.

Canonical TPU tiling: grid = (batch·heads, q_tiles, kv_tiles) with the
kv dimension LAST (sequential on TPU), so the VMEM scratch (running max,
denominator, f32 accumulator) carries across kv steps while BlockSpecs
pipeline the HBM→VMEM tile copies.  Causal and sliding-window masks are
applied per tile; tiles entirely outside the mask are skipped with
``pl.when`` (zero MXU work — the sequence-axis neighborhood property).

Block shapes default to (128, 128): MXU-aligned (multiples of 128 on both
matmul dims), and the working set per step —
q(128×D) + k(128×D) + v(128×D) + acc(128×D f32) + scores(128×128 f32) —
stays well under VMEM for D ≤ 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bk: int, causal: bool, window: int | None,
            scale: float, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = qi * bq
    k0 = ki * bk
    # static-ish tile relevance test (depends only on program ids)
    relevant = jnp.asarray(True)
    if causal:
        relevant = relevant & (k0 <= q0 + bq - 1)
    if window is not None:
        relevant = relevant & (k0 + bk - 1 >= q0 - window + 1)

    @pl.when(relevant)
    def _step():
        q = q_ref[0]  # [bq, D]
        k = k_ref[0]  # [bk, D]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        qp = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qp >= kp
        if window is not None:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q, k, v,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    """q/k/v: [B, H, S, D] → [B, H, S, D]."""
    b, h, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    if s % bq or s % bk:
        raise ValueError(f"S={s} must be a multiple of block sizes {bq},{bk}")
    nq, nk = s // bq, s // bk
    bh = b * h
    qr = q.reshape(bh, s, d)
    kr = k.reshape(bh, s, d)
    vr = v.reshape(bh, s, d)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=d**-0.5, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # f32 output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)

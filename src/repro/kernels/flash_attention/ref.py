"""Pure-jnp oracle: naive masked attention (causal / sliding window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None):
    """q/k/v: [B, H, S, D] → [B, H, S, D] (f32 math)."""
    b, h, s, d = q.shape
    sc = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * d**-0.5
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)

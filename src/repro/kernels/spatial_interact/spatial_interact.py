"""Pallas TPU kernel for the paper's query-phase hot loop.

TPU adaptation of the spatial join inner loop: agents are pre-sorted by
their grid cell (equivalently by x for 1-D slabs), so all interaction
partners of a query tile live within a bounded *index band*.  The kernel
tiles queries over the grid's first dimension and sweeps candidate tiles
along the second (sequential) dimension, skipping tiles outside the band —
cell-list locality turned into static tile masking (dense, VPU-friendly;
no pointer chasing like the paper's KD-tree).

Layout: agent coordinates/headings as [N] f32 vectors in VMEM; output
accumulators [N, 8] (see ref.py for channel semantics), accumulated across
the sequential candidate sweep in the revisited output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import N_CHANNELS

DEF_TQ = 256
DEF_TK = 256


def _kernel(x_ref, y_ref, hx_ref, hy_ref, alive_ref, out_ref,
            *, alpha: float, rho: float, tq: int, tk: int, band: int):
    qi = pl.program_id(0)
    ki = pl.program_id(1)
    q0 = qi * tq
    k0 = ki * tk

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # band test on index ranges (agents sorted by x ⇒ partners are near in
    # index space); band >= n disables skipping (the Fig. 3 baseline)
    in_band = (k0 + tk > q0 - band) & (k0 < q0 + tq + band)

    @pl.when(in_band)
    def _compute():
        xq = x_ref[pl.ds(q0, tq)]
        yq = y_ref[pl.ds(q0, tq)]
        aq = alive_ref[pl.ds(q0, tq)]
        xk = x_ref[pl.ds(k0, tk)]
        yk = y_ref[pl.ds(k0, tk)]
        hxk = hx_ref[pl.ds(k0, tk)]
        hyk = hy_ref[pl.ds(k0, tk)]
        ak = alive_ref[pl.ds(k0, tk)]

        eps = 1e-6
        dx = xk[None, :] - xq[:, None]   # [TQ, TK]
        dy = yk[None, :] - yq[:, None]
        d2 = dx * dx + dy * dy
        d = jnp.sqrt(d2) + eps

        qidx = q0 + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        kidx = k0 + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        pair = (aq[:, None] > 0) & (ak[None, :] > 0) & (qidx != kidx)
        vis = pair & (d2 <= rho * rho)
        rep = vis & (d2 < alpha * alpha)
        att = vis & ~rep

        def acc(mask, val):
            return jnp.sum(jnp.where(mask, val, 0.0), axis=1)

        ones = jnp.ones_like(d)
        block = jnp.stack(
            [
                acc(rep, -dx / d),
                acc(rep, -dy / d),
                acc(att, dx / d),
                acc(att, dy / d),
                acc(att, jnp.broadcast_to(hxk[None, :], d.shape)),
                acc(att, jnp.broadcast_to(hyk[None, :], d.shape)),
                acc(rep, ones),
                acc(att, ones),
            ],
            axis=-1,
        )  # [TQ, 8]
        out_ref[...] += block


def spatial_interact_pallas(
    x, y, hx, hy, alive,
    *,
    alpha: float,
    rho: float,
    band: int | None = None,
    tq: int = DEF_TQ,
    tk: int = DEF_TK,
    interpret: bool = False,
):
    """x/y/hx/hy: [N] f32 (N % tile == 0; sorted by x when banding);
    alive: [N] bool/int.  Returns [N, 8] f32 accumulators.

    ``band``: max index distance between interacting pairs after sorting;
    None = full O(N²) sweep (the no-index baseline of Fig. 3).
    """
    n = x.shape[0]
    tq = min(tq, n)
    tk = min(tk, n)
    if n % tq or n % tk:
        raise ValueError(f"N={n} must be a multiple of tile sizes {tq},{tk}")
    nq, nk = n // tq, n // tk
    band_agents = n if band is None else int(band)
    alive_f = alive.astype(jnp.float32)

    kernel = functools.partial(
        _kernel, alpha=alpha, rho=rho, tq=tq, tk=tk, band=band_agents,
    )
    return pl.pallas_call(
        kernel,
        grid=(nq, nk),
        in_specs=[pl.BlockSpec((n,), lambda qi, ki: (0,))] * 5,
        out_specs=pl.BlockSpec((tq, N_CHANNELS), lambda qi, ki: (qi, 0)),
        out_shape=jax.ShapeDtypeStruct((n, N_CHANNELS), jnp.float32),
        interpret=interpret,
    )(x, y, hx, hy, alive_f)

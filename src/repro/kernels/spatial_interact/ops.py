"""Jitted wrapper: pads to tile multiples, computes a sound index band
from the visibility radius, sorts by x, runs the kernel, unsorts."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ref import N_CHANNELS, spatial_interact_ref
from .spatial_interact import DEF_TK, DEF_TQ, spatial_interact_pallas


@partial(jax.jit, static_argnames=("alpha", "rho", "band", "interpret", "tq", "tk"))
def spatial_interact(
    x, y, hx, hy, alive,
    *,
    alpha: float,
    rho: float,
    band: int | None = None,
    tq: int = DEF_TQ,
    tk: int = DEF_TK,
    interpret: bool = False,
):
    """Sorted-banded spatial interaction; returns [N, 8] in input order."""
    n = x.shape[0]
    tq = min(tq, max(8, n))
    tk = min(tk, max(8, n))
    pad = (-n) % max(tq, tk)
    if pad:
        z = jnp.zeros((pad,), x.dtype)
        x = jnp.concatenate([x, z])
        y = jnp.concatenate([y, z])
        hx = jnp.concatenate([hx, z])
        hy = jnp.concatenate([hy, z])
        alive = jnp.concatenate([alive, jnp.zeros((pad,), alive.dtype)])

    order = jnp.argsort(jnp.where(alive, x, 3e38))
    inv = jnp.argsort(order)
    out = spatial_interact_pallas(
        x[order], y[order], hx[order], hy[order], alive[order],
        alpha=alpha, rho=rho, band=band, tq=tq, tk=tk, interpret=interpret,
    )
    return out[inv][:n]


def spatial_interact_reference(x, y, hx, hy, alive, *, alpha, rho):
    return spatial_interact_ref(x, y, hx, hy, alive, alpha, rho)

"""Pure-jnp oracle for the spatial interaction kernel.

Computes the Couzin-style zonal accumulators for every agent i over all
agents j (the paper's query-phase hot loop):

    dist < α   (repulsion zone):  rx += -dx/d, ry += -dy/d, cnt_r += 1
    α ≤ dist < ρ (attract/orient): ax += dx/d, ay += dy/d,
                                   ox += hx_j, oy += hy_j, cnt_a += 1

Output channels: [rx, ry, ax, ay, ox, oy, cnt_r, cnt_a]  → [N, 8].
"""

from __future__ import annotations

import jax.numpy as jnp

N_CHANNELS = 8


def spatial_interact_ref(x, y, hx, hy, alive, alpha: float, rho: float):
    eps = 1e-6
    dx = x[None, :] - x[:, None]   # [i, j] = j relative to i
    dy = y[None, :] - y[:, None]
    d2 = dx * dx + dy * dy
    d = jnp.sqrt(d2) + eps
    pair = alive[:, None] & alive[None, :]
    n = x.shape[0]
    pair = pair & ~jnp.eye(n, dtype=bool)
    vis = pair & (d2 <= rho * rho)
    rep = vis & (d2 < alpha * alpha)
    att = vis & ~rep

    def acc(mask, val):
        return jnp.sum(jnp.where(mask, val, 0.0), axis=1)

    rx = acc(rep, -dx / d)
    ry = acc(rep, -dy / d)
    ax = acc(att, dx / d)
    ay = acc(att, dy / d)
    ox = acc(att, jnp.broadcast_to(hx[None, :], d.shape))
    oy = acc(att, jnp.broadcast_to(hy[None, :], d.shape))
    cr = acc(rep, jnp.ones_like(d))
    ca = acc(att, jnp.ones_like(d))
    return jnp.stack([rx, ry, ax, ay, ox, oy, cr, ca], axis=-1)
